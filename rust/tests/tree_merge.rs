//! Root-parallel tree-merge integration tests: the merge differential
//! contract (at equal total sample budget the merged tree's incumbent is
//! at least the best single lane's, on every registry workload and on
//! parameterized scenarios), bit-determinism per (seed-set, N), the
//! merge identities (single lane ≡ plain search, merging against a
//! missing lane ≡ the tree alone), and the corruption suite (a
//! truncated / garbage / version-mismatched / dangling-parent lane
//! snapshot is skipped with a warning and never poisons the surviving
//! lanes — their merge is bit-identical to a healthy-lanes-only merge).
//!
//! Mirrors `tree_persist.rs` for the persistence layer; this file locks
//! the merge layer above it (`litecoop::mcts::treemerge`).

use litecoop::llm::registry::paper_config;
use litecoop::llm::ModelSet;
use litecoop::mcts::treemerge::{merge_engines, merge_snapshot_files};
use litecoop::mcts::{Mcts, SearchConfig};
use litecoop::schedule::Schedule;
use litecoop::sim::{Simulator, Target};
use litecoop::util::Json;
use litecoop::workloads;
use std::sync::Arc;

/// The six registry workloads plus two parameterized scenario points —
/// the differential contract's coverage set.
const DIFFERENTIAL_SET: [&str; 8] = [
    "llama3_attention",
    "deepseek_moe",
    "flux_attention",
    "flux_conv",
    "llama4_mlp",
    "gemm",
    "gemm@m=128,n=128",
    "attention@seq=128",
];

/// Unique temp path per test (tests run concurrently in one process).
fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("litecoop_tree_merge_{tag}_{}.json", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

/// The process-local pieces a lane snapshot cannot carry.
fn fresh_parts(scenario: &str) -> (ModelSet, Simulator, Schedule) {
    let w = workloads::resolve(scenario).unwrap();
    (
        ModelSet::new(paper_config(2, "gpt-5.2")),
        Simulator::new(Target::Cpu),
        Schedule::initial(Arc::new(w)),
    )
}

/// One finished lane: an independent fixed-seed search of `scenario`.
fn lane(scenario: &str, seed: u64, budget: usize) -> Mcts {
    let (models, sim, root) = fresh_parts(scenario);
    let cfg = SearchConfig {
        budget,
        seed,
        checkpoints: vec![budget / 2, budget],
        ..SearchConfig::default()
    };
    Mcts::new(cfg, models, sim, root).run_until(budget)
}

fn snap_string(e: &Mcts) -> String {
    format!("{}", e.snapshot())
}

// ----------------------------------------------------------- differential

#[test]
fn merged_result_dominates_every_lane_across_workloads_and_scenarios() {
    // N lanes at budget B/N each vs the merged tree at total budget B:
    // the merged incumbent must match the best lane's bit for bit (never
    // below it), the sample ledger must cover the full budget, and the
    // merged tree must pass the legality analyzer tree-wide.
    for scenario in DIFFERENTIAL_SET {
        let lanes: Vec<Mcts> = [1u64, 2].iter().map(|&s| lane(scenario, s, 12)).collect();
        let speedups: Vec<f64> = lanes.iter().map(Mcts::best_speedup).collect();
        let best = speedups.iter().cloned().fold(f64::MIN, f64::max);
        let total: usize = lanes.iter().map(Mcts::samples).sum();
        assert_eq!(total, 24, "{scenario}: lanes under-sampled their budgets");

        let merged = merge_engines(lanes).unwrap_or_else(|e| panic!("{scenario}: {e}"));
        for (i, &s) in speedups.iter().enumerate() {
            assert!(
                merged.best_speedup() >= s,
                "{scenario}: merged speedup {} below lane {i}'s {s}",
                merged.best_speedup()
            );
        }
        assert_eq!(
            merged.best_speedup().to_bits(),
            best.to_bits(),
            "{scenario}: merged incumbent is not the best lane's"
        );
        assert_eq!(merged.samples(), total, "{scenario}: sample ledger drifted");
        assert_eq!(merged.first_tree_deny(), None, "{scenario}: merged tree lints dirty");
    }
}

#[test]
fn merged_tree_is_bit_deterministic_per_seed_set() {
    // the merged tree is a pure function of (scenario, seed set, N):
    // rebuilding the lanes from scratch and re-merging reproduces the
    // canonical serialization byte for byte.
    let build = || {
        let lanes: Vec<Mcts> = [5u64, 9, 13].iter().map(|&s| lane("gemm", s, 10)).collect();
        snap_string(&merge_engines(lanes).unwrap())
    };
    assert_eq!(build(), build(), "same (seed-set, N) produced different merged trees");
}

// -------------------------------------------------------------- identities

#[test]
fn single_lane_file_merge_is_plain_search() {
    // merging a one-element lane list is the identity: the merged tree
    // re-serializes to exactly the snapshot the plain search persisted.
    let path = tmp_path("single");
    lane("gemm", 3, 16).save_file(&path).unwrap();
    let persisted = std::fs::read_to_string(&path).unwrap();

    let (merged, report) =
        merge_snapshot_files(&[path.clone()], || fresh_parts("gemm")).unwrap();
    assert_eq!(report.lanes_merged, 1);
    assert!(report.skipped.is_empty(), "{:?}", report.skipped);
    assert_eq!(format!("{}\n", merged.snapshot()), persisted);
    std::fs::remove_file(&path).ok();
}

#[test]
fn merge_with_missing_lane_is_identity() {
    // merge(tree, empty) ≡ tree: a lane that never produced a snapshot
    // is skipped, and the surviving tree passes through untouched.
    let path = tmp_path("present");
    let ghost = tmp_path("ghost_never_written");
    std::fs::remove_file(&ghost).ok();
    lane("gemm", 11, 16).save_file(&path).unwrap();
    let persisted = std::fs::read_to_string(&path).unwrap();

    let (merged, report) =
        merge_snapshot_files(&[path.clone(), ghost.clone()], || fresh_parts("gemm")).unwrap();
    assert_eq!(report.lanes_merged, 1);
    assert_eq!(report.skipped.len(), 1);
    assert_eq!(report.skipped[0].0, ghost);
    assert_eq!(report.skipped[0].1, "missing");
    assert_eq!(format!("{}\n", merged.snapshot()), persisted);
    std::fs::remove_file(&path).ok();
}

// -------------------------------------------------------- corruption suite

#[test]
fn corrupt_lane_snapshots_never_poison_the_surviving_lanes() {
    // two healthy lanes plus one corrupt lane file, for every corruption
    // mode: the merge must degrade to skipping the corrupt lane — never
    // a panic — and the result must be bit-identical to a merge that
    // only ever saw the healthy files.
    let p1 = tmp_path("healthy_1");
    let p2 = tmp_path("healthy_2");
    let p3 = tmp_path("corrupt_3");
    lane("gemm", 1, 16).save_file(&p1).unwrap();
    lane("gemm", 2, 16).save_file(&p2).unwrap();
    let (healthy, healthy_report) =
        merge_snapshot_files(&[p1.clone(), p2.clone()], || fresh_parts("gemm")).unwrap();
    assert_eq!(healthy_report.lanes_merged, 2);
    let healthy_snap = snap_string(&healthy);

    // a valid third lane to corrupt, via structured surgery on the
    // parsed snapshot (the same idiom as tree_persist.rs)
    let valid = snap_string(&lane("gemm", 3, 16));
    let mutate = |f: &dyn Fn(&mut Json)| {
        let mut v = Json::parse(&valid).unwrap();
        f(&mut v);
        format!("{v}")
    };
    let cases: Vec<(&str, String)> = vec![
        ("truncated file", valid[..valid.len() / 2].to_string()),
        ("garbage bytes", "this is not { json".to_string()),
        (
            "unsupported version",
            mutate(&|v| {
                v.set("version", Json::Num(99.0));
            }),
        ),
        (
            "dangling parent index",
            mutate(&|v| {
                if let Json::Obj(m) = v {
                    if let Some(Json::Arr(nodes)) = m.get_mut("nodes") {
                        nodes[1].set("parent", Json::Num(1_000_000.0));
                    }
                }
            }),
        ),
    ];

    for (what, text) in &cases {
        std::fs::write(&p3, text).unwrap();
        let (merged, report) =
            merge_snapshot_files(&[p1.clone(), p2.clone(), p3.clone()], || fresh_parts("gemm"))
                .unwrap_or_else(|e| panic!("{what}: merge refused to degrade: {e}"));
        assert_eq!(report.lanes_merged, 2, "{what}: wrong lane count");
        assert_eq!(report.skipped.len(), 1, "{what}: {:?}", report.skipped);
        assert_eq!(report.skipped[0].0, p3, "{what}");
        assert!(!report.skipped[0].1.is_empty(), "{what}: empty skip reason");
        assert_eq!(
            snap_string(&merged),
            healthy_snap,
            "{what}: corrupt lane leaked into the merged tree"
        );
    }

    // no healthy lane at all is the one hard error
    std::fs::write(&p3, "still not json").unwrap();
    let ghost = tmp_path("corrupt_ghost");
    std::fs::remove_file(&ghost).ok();
    let err = merge_snapshot_files(&[p3.clone(), ghost], || fresh_parts("gemm"))
        .err()
        .expect("all-corrupt merge must fail");
    assert!(err.contains("no healthy lane"), "{err}");

    for p in [&p1, &p2, &p3] {
        std::fs::remove_file(p).ok();
    }
}

// ------------------------------------------------------------- resumability

#[test]
fn merged_snapshot_resumes_from_disk_and_keeps_searching() {
    // a merged tree persisted to disk is a first-class registry tree:
    // it reloads, re-serializes byte-identically, and continues the
    // search with a monotone incumbent.
    let path = tmp_path("resume");
    let lanes: Vec<Mcts> = [4u64, 8].iter().map(|&s| lane("gemm", s, 14)).collect();
    let merged = merge_engines(lanes).unwrap();
    let before_speedup = merged.best_speedup();
    let before_samples = merged.samples();
    merged.save_file(&path).unwrap();

    let (models, sim, root) = fresh_parts("gemm");
    let mut resumed = Mcts::load_file(&path, models, sim, root).unwrap();
    assert_eq!(format!("{}\n", resumed.snapshot()), std::fs::read_to_string(&path).unwrap());
    assert_eq!(resumed.samples(), before_samples);
    resumed.extend_budget(8);
    let done = resumed.run_until(usize::MAX);
    assert_eq!(done.samples(), before_samples + 8);
    assert!(done.best_speedup() >= before_speedup, "incumbent regressed after resume");
    std::fs::remove_file(&path).ok();
}

//! Deterministic property/fuzz harness over the whole search stack —
//! dependency-free (proptest is unavailable offline).
//!
//! Every property runs ≥ 200 random cases. Case seeds derive from a
//! per-property base via [`litecoop::util::rng::splitmix64`], so the
//! stream is stable across runs and platforms; on failure the harness
//! panics with the exact case seed and replay instructions.

use litecoop::mcts::evalcache::{trace_key, CacheStats, EvalCache, SharedEvalCache};
use litecoop::mcts::fill_missing_checkpoints;
use litecoop::schedule::printer::print_dominant;
use litecoop::schedule::transforms::{apply, TransformKind};
use litecoop::schedule::Schedule;
use litecoop::sim::Target;
use litecoop::util::rng::splitmix64;
use litecoop::util::Rng;
use litecoop::workloads;
use litecoop::workloads::scenarios::{Family, ScenarioSpec};
use std::sync::Arc;

/// Run `cases` random cases of `prop`; case seeds come from a splitmix64
/// stream over `base`. On failure, panics with the seed and how to replay
/// exactly that case.
fn check<F>(name: &str, cases: usize, base: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut state = base;
    for case in 0..cases {
        let seed = splitmix64(&mut state);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases}, seed {seed:#018x}: {msg}\n\
                 replay: seed the property body with litecoop::util::Rng::new({seed:#018x}) \
                 (case seeds are splitmix64({base:#x}) stream position {case})"
            );
        }
    }
}

/// A random built-in workload: the five paper benchmarks plus the GEMM
/// micro-workload, with randomized GEMM dimensions for structural variety.
fn random_workload(rng: &mut Rng) -> litecoop::tir::Workload {
    match rng.below(7) {
        0 => workloads::attention::llama3_attention(),
        1 => workloads::moe::deepseek_moe(),
        2 => workloads::attention::flux_attention(),
        3 => workloads::conv::flux_conv(),
        4 => workloads::mlp::llama4_mlp(),
        5 => workloads::gemm::gemm(256, 256, 256),
        _ => {
            let dims = [64i64, 128, 256, 512];
            workloads::gemm::gemm(
                *rng.choice(&dims),
                *rng.choice(&dims),
                *rng.choice(&dims),
            )
        }
    }
}

/// Apply up to `max_steps` random transforms (skipping inapplicable
/// ones), returning the final schedule.
fn random_schedule(base: &Schedule, max_steps: usize, gpu: bool, rng: &mut Rng) -> Schedule {
    let vocab = TransformKind::vocabulary(gpu);
    let mut s = base.clone();
    for _ in 0..max_steps {
        let k = *rng.choice(&vocab);
        if let Ok(next) = apply(&s, k, rng, gpu) {
            s = next;
        }
    }
    s
}

// ---------------------------------------------------------------- property 1

#[test]
fn prop_random_transform_sequences_keep_schedules_well_formed() {
    // any legal random transform sequence, on any built-in workload, on
    // either target: the schedule stays structurally valid after every
    // step, prints without panicking, and its fingerprint / trace hash
    // are stable across clones
    check("schedule-well-formed", 200, 0x5EED_0001, |rng| {
        let gpu = rng.chance(0.5);
        let w = random_workload(rng);
        let name = w.name.clone();
        let mut s = Schedule::initial(Arc::new(w));
        let vocab = TransformKind::vocabulary(gpu);
        let steps = 1 + rng.below(12);
        let mut applied = 0usize;
        for _ in 0..steps {
            let k = *rng.choice(&vocab);
            let next = match apply(&s, k, rng, gpu) {
                Ok(n) => n,
                Err(_) => continue, // structural no-fit, not a failure
            };
            applied += 1;
            next.validate()
                .map_err(|e| format!("{name}: invalid after {k:?}: {e}"))?;
            if next.trace.len() != s.trace.len() + 1 {
                return Err(format!(
                    "{name}: trace len {} != {} + 1 after {k:?}",
                    next.trace.len(),
                    s.trace.len()
                ));
            }
            s = next;
        }
        // rendering never panics and never goes empty
        let rendered = print_dominant(&s, gpu);
        if rendered.is_empty() {
            return Err(format!("{name}: empty rendering"));
        }
        let _ = s.trace.render_tail(8);
        // fingerprint + trace hash stable across clone (CoW sharing)
        let c = s.clone();
        if s.fingerprint() != c.fingerprint() {
            return Err(format!("{name}: fingerprint unstable across clone"));
        }
        if s.trace.running_hash() != c.trace.running_hash() {
            return Err(format!("{name}: trace hash unstable across clone"));
        }
        if applied != s.trace.len() {
            return Err(format!(
                "{name}: applied {applied} != trace len {}",
                s.trace.len()
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- property 2

#[test]
fn prop_trace_key_equality_iff_structural_equality() {
    // collision smoke test over > 10k random schedule pairs: equal keys
    // must mean equal (trace, workload, target, structure); and rebuilt /
    // cloned schedules (structural equality) must produce equal keys.
    let mut pairs_checked = 0usize;
    let mut key_hits = 0usize;
    check("trace-key-bijective", 200, 0x5EED_0002, |rng| {
        // a small pool per case: same-workload prefixes make key
        // collisions as likely as they ever get
        let gpu = rng.chance(0.5);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        let base = Schedule::initial(Arc::new(random_workload(rng)));
        let mut pool: Vec<Schedule> = (0..9)
            .map(|_| random_schedule(&base, rng.below(4), gpu, rng))
            .collect();
        // include the base itself and one literal clone: guaranteed
        // structurally-equal pairs exercising the ⇐ direction
        pool.push(base.clone());
        pool.push(pool[0].clone());
        let keys: Vec<u64> = pool.iter().map(|s| trace_key(s, target)).collect();
        for i in 0..pool.len() {
            for j in (i + 1)..pool.len() {
                pairs_checked += 1;
                let keys_equal = keys[i] == keys[j];
                let structurally_equal = pool[i].trace == pool[j].trace
                    && pool[i].workload.name == pool[j].workload.name
                    && pool[i].fingerprint() == pool[j].fingerprint();
                if keys_equal {
                    key_hits += 1;
                }
                if keys_equal != structurally_equal {
                    return Err(format!(
                        "pair ({i},{j}): key equality {keys_equal} but structural \
                         equality {structurally_equal} (keys {:#x} vs {:#x})",
                        keys[i], keys[j]
                    ));
                }
            }
        }
        // cross-target: the same program must never share a key across
        // targets
        let s = &pool[0];
        if trace_key(s, Target::Cpu) == trace_key(s, Target::Gpu) {
            return Err("key ignores target".into());
        }
        // rebuilt from the same decision stream -> same key (⇐ direction
        // across distinct allocations, not just clones)
        let mut ra = Rng::new(rng.next_u64());
        let mut rb = ra.clone();
        let a = random_schedule(&base, 3, gpu, &mut ra);
        let b = random_schedule(&base, 3, gpu, &mut rb);
        if trace_key(&a, target) != trace_key(&b, target) {
            return Err("identical decision streams produced different keys".into());
        }
        Ok(())
    });
    assert!(
        pairs_checked >= 10_000,
        "only {pairs_checked} pairs checked"
    );
    assert!(
        key_hits >= 200,
        "only {key_hits} equal-key pairs seen — the ⇒ direction was barely exercised"
    );
}

// ---------------------------------------------------------------- property 3

#[test]
fn prop_fill_missing_checkpoints_is_monotone_and_complete() {
    // for random partial curves and random checkpoint grids: the filled
    // curve is sorted by sample count (monotone in checkpoint index),
    // contains every configured checkpoint exactly once, preserves the
    // points the search actually recorded, and carries `final_speedup`
    // into every checkpoint it had to invent.
    check("checkpoints-complete", 200, 0x5EED_0003, |rng| {
        // random strictly-increasing checkpoint grid
        let n = 1 + rng.below(8);
        let mut checkpoints = Vec::with_capacity(n);
        let mut cp = 0usize;
        for _ in 0..n {
            cp += 1 + rng.below(300);
            checkpoints.push(cp);
        }
        // a random subset of the grid is already on the curve, with
        // random recorded speedups
        let mut curve: Vec<(usize, f64)> = checkpoints
            .iter()
            .filter(|_| rng.chance(0.5))
            .map(|&c| (c, 1.0 + rng.f64() * 9.0))
            .collect();
        // plus possibly an off-grid final point, as run() pushes
        if rng.chance(0.5) {
            curve.push((cp + 1 + rng.below(50), 1.0 + rng.f64() * 9.0));
        }
        let recorded = curve.clone();
        let final_speedup = 1.0 + rng.f64() * 9.0;
        fill_missing_checkpoints(&mut curve, &checkpoints, final_speedup);

        for w in curve.windows(2) {
            if w[1].0 <= w[0].0 {
                return Err(format!("not strictly sorted: {curve:?}"));
            }
        }
        for &c in &checkpoints {
            let hits = curve.iter().filter(|&&(s, _)| s == c).count();
            if hits != 1 {
                return Err(format!("checkpoint {c} appears {hits} times: {curve:?}"));
            }
        }
        for &(s, v) in &recorded {
            if !curve.contains(&(s, v)) {
                return Err(format!("recorded point ({s}, {v}) was altered: {curve:?}"));
            }
        }
        for &(s, v) in &curve {
            if !recorded.iter().any(|&(rs, _)| rs == s) && v != final_speedup {
                return Err(format!(
                    "invented point ({s}, {v}) != final speedup {final_speedup}"
                ));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- property 4

#[test]
fn prop_shared_cache_is_observationally_equal_to_serial_cache() {
    // drive a random op sequence through an EvalCache and a
    // SharedEvalCache (random shard count) in lockstep: every returned
    // value, every served flag, and the final counters must agree —
    // the transparency contract the tree-parallel engine relies on.
    check("shared-cache-transparent", 200, 0x5EED_0004, |rng| {
        let mut serial = EvalCache::new();
        let shared = SharedEvalCache::new(1 + rng.below(8));
        let key_space: u64 = 1 + rng.below(12) as u64;
        for step in 0..40 {
            if rng.chance(0.7) {
                let key = rng.next_u64() % key_space;
                let val = (key as f64 + 1.0) * 0.25; // pure function of key
                let (sv, s_served) = serial.latency_or_served(key, || val);
                let (cv, c_served) = shared.latency_or_served(key, || val);
                if sv != cv || s_served != c_served {
                    return Err(format!(
                        "step {step} key {key}: serial ({sv}, {s_served}) vs \
                         shared ({cv}, {c_served})"
                    ));
                }
            } else {
                let key = (rng.next_u64() % key_space, 7u64, rng.below(2));
                let val = (key.0 as f64 + 1.0) * 0.5 + key.2 as f64;
                let sv = serial.prediction_or(key, || val);
                let cv = shared.prediction_or(key, || val);
                if sv != cv {
                    return Err(format!("step {step} pred {key:?}: {sv} vs {cv}"));
                }
            }
        }
        if serial.stats() != shared.stats() {
            return Err(format!(
                "counters diverged: serial {:?} vs shared {:?}",
                serial.stats(),
                shared.stats()
            ));
        }
        let drained = shared.into_cache();
        if drained.len() != serial.len() {
            return Err(format!(
                "entry counts diverged: serial {} vs drained {}",
                serial.len(),
                drained.len()
            ));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- scenarios

/// A random scenario point: random family, each key set with some
/// probability from constraint-respecting value pools (so every
/// generated spec is *expected* to lower — lowering failures are
/// property violations, not generator noise).
fn random_scenario(rng: &mut Rng) -> ScenarioSpec {
    let family = *rng.choice(&Family::ALL);
    let mut spec = ScenarioSpec::new(family);
    let dims = [1i64, 2, 3, 4, 8, 16, 24, 32, 48, 64, 96, 128, 256, 512];
    let dtypes = ["f32", "bf16", "f16", "i32"];
    let mut put = |spec: &mut ScenarioSpec, key: &str, val: String| {
        spec.set(key, &val).unwrap_or_else(|e| panic!("generator produced invalid {key}: {e}"))
    };
    let mut maybe_int = |spec: &mut ScenarioSpec, rng: &mut Rng, key: &str| {
        if rng.chance(0.7) {
            let v = *rng.choice(&dims);
            spec.set(key, &v.to_string()).unwrap();
        }
    };
    match family {
        Family::Gemm => {
            for key in ["m", "n", "k", "batch"] {
                maybe_int(&mut spec, rng, key);
            }
        }
        Family::Attention | Family::LlamaE2e => {
            let causal = rng.chance(0.5);
            put(&mut spec, "causal", causal.to_string());
            if rng.chance(0.7) {
                // causal needs seq >= 2
                let seqs = [2i64, 3, 4, 16, 64, 128, 256, 512];
                put(&mut spec, "seq", rng.choice(&seqs).to_string());
            }
            maybe_int(&mut spec, rng, "heads");
            maybe_int(&mut spec, rng, "head_dim");
            if family == Family::LlamaE2e {
                maybe_int(&mut spec, rng, "d_ff");
            }
        }
        Family::Conv => {
            // kernel must fit the input: h,w >= 8, kh,kw <= 7
            let hw = [8i64, 16, 32, 64, 96];
            let ks = [1i64, 2, 3, 5, 7];
            put(&mut spec, "h", rng.choice(&hw).to_string());
            put(&mut spec, "w", rng.choice(&hw).to_string());
            put(&mut spec, "kh", rng.choice(&ks).to_string());
            put(&mut spec, "kw", rng.choice(&ks).to_string());
            maybe_int(&mut spec, rng, "c_in");
            maybe_int(&mut spec, rng, "c_out");
        }
        Family::Mlp => {
            for key in ["tokens", "d_model", "d_ff"] {
                maybe_int(&mut spec, rng, key);
            }
        }
        Family::Moe => {
            for key in ["tokens", "d_model", "d_ff"] {
                maybe_int(&mut spec, rng, key);
            }
            // top_k <= experts
            let experts = 1 + rng.below(8) as i64;
            put(&mut spec, "experts", experts.to_string());
            put(&mut spec, "top_k", (1 + rng.below(experts as usize) as i64).to_string());
        }
    }
    if rng.chance(0.5) {
        put(&mut spec, "dtype", rng.choice(&dtypes).to_string());
    }
    spec
}

#[test]
fn prop_scenarios_lower_well_formed_and_names_roundtrip() {
    // every generated ScenarioSpec (a) lowers to a well-formed workload
    // (validated, non-empty blocks, in-bounds buffer refs, stable
    // fingerprint) and (b) round-trips through its canonical name:
    // parse(name) reproduces the spec, and by_name(name) reproduces the
    // lowered workload.
    check("scenario-lower-roundtrip", 200, 0x5CE_A210, |rng| {
        let spec = random_scenario(rng);
        let name = spec.name();
        let w = spec
            .lower()
            .map_err(|e| format!("{name}: failed to lower: {e}"))?;
        if w.blocks.is_empty() {
            return Err(format!("{name}: no blocks"));
        }
        w.validate().map_err(|e| format!("{name}: invalid: {e}"))?;
        for blk in &w.blocks {
            for acc in blk.reads.iter().chain(blk.writes.iter()) {
                if acc.buffer >= w.buffers.len() {
                    return Err(format!("{name}: buffer ref {} oob", acc.buffer));
                }
            }
        }
        if w.name != name {
            return Err(format!("{name}: lowered name {:?} differs", w.name));
        }
        // canonical-name fixed point and spec round-trip
        let reparsed =
            ScenarioSpec::parse(&name).map_err(|e| format!("{name}: reparse failed: {e}"))?;
        if reparsed != spec || reparsed.name() != name {
            return Err(format!("{name}: parse∘name is not a fixed point"));
        }
        // lowering is deterministic: same flops, same structure, same
        // initial-schedule fingerprint, twice in a row and via by_name
        let again = spec.lower().map_err(|e| format!("{name}: relower: {e}"))?;
        let by_name = workloads::by_name(&name)
            .ok_or_else(|| format!("{name}: by_name failed to resolve"))?;
        for (tag, other) in [("relower", &again), ("by_name", &by_name)] {
            if other.flops() != w.flops() || other.blocks.len() != w.blocks.len() {
                return Err(format!("{name}: {tag} structure drifted"));
            }
            let fp_a = Schedule::initial(Arc::new(w.clone())).fingerprint();
            let fp_b = Schedule::initial(Arc::new(other.clone())).fingerprint();
            if fp_a != fp_b {
                return Err(format!("{name}: {tag} fingerprint unstable"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_incremental_latency_is_bit_identical_to_full() {
    // the incremental-evaluation contract (tentpole of the block-memo
    // subsystem): with the thread-local per-block memo warming up across
    // a transform storm — exactly the mutate-one-block-then-evaluate
    // pattern the search generates — `Simulator::latency` must equal
    // `Simulator::latency_full` bit for bit at EVERY step, across all six
    // scenario families and both targets. Cases share one OS thread, so
    // the memo also carries entries across workloads/specs within the
    // property — any key collision or missing key component (a cross-
    // block dependency not folded in) surfaces as a bit mismatch here.
    use litecoop::sim::Simulator;
    let mut families_seen = std::collections::BTreeSet::new();
    let mut targets_seen = std::collections::BTreeSet::new();
    check("incremental-latency-bit-identical", 200, 0x5EED_0005, |rng| {
        let spec = random_scenario(rng);
        let name = spec.name();
        let w = spec.lower().map_err(|e| format!("{name}: lower: {e}"))?;
        families_seen.insert(name.split('@').next().unwrap_or("").to_string());
        let gpu = rng.chance(0.5);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        targets_seen.insert(format!("{target:?}"));
        let sim = Simulator::new(target);
        let vocab = TransformKind::vocabulary(gpu);
        let mut s = Schedule::initial(Arc::new(w));
        // baseline/speedup path exercised too (memoized vs rebuilt)
        let sp0 = sim.speedup(&s);
        if (sp0 - 1.0).abs() > 1e-9 {
            return Err(format!("{name}: initial speedup {sp0} != 1"));
        }
        for step in 0..(4 + rng.below(12)) {
            if let Ok(next) = apply(&s, *rng.choice(&vocab), rng, gpu) {
                s = next;
            }
            let inc = sim.latency(&s);
            let full = sim.latency_full(&s);
            if inc.to_bits() != full.to_bits() {
                return Err(format!(
                    "{name} ({target:?}) step {step}: incremental {inc:e} \
                     (bits {:#018x}) != full {full:e} (bits {:#018x})",
                    inc.to_bits(),
                    full.to_bits()
                ));
            }
            // the memoized speedup must equal the hand-computed ratio of
            // full recomputes, bit for bit
            let sp = sim.speedup(&s);
            let sp_full =
                sim.latency_full(&Schedule::initial(s.workload.clone())) / full;
            if sp.to_bits() != sp_full.to_bits() {
                return Err(format!(
                    "{name} ({target:?}) step {step}: memoized speedup {sp} != \
                     full-recompute speedup {sp_full}"
                ));
            }
        }
        Ok(())
    });
    assert_eq!(
        families_seen.len(),
        6,
        "all six scenario families must be exercised, saw {families_seen:?}"
    );
    assert_eq!(targets_seen.len(), 2, "both targets must be exercised");
}

#[test]
fn prop_spec_edited_simulators_keep_precomputed_keys_honest() {
    // the precomputed-instance-key contract (`Simulator::instance_key`):
    // editing a spec re-folds the stored key prefix, so differently-specced
    // simulators interleaving on ONE thread-local block memo must each stay
    // bit-identical to their own full recompute at every step — a stale or
    // colliding prefix would surface here as one simulator serving the
    // other's memoized block contributions.
    use litecoop::sim::Simulator;
    check("spec-edit-instance-keys", 120, 0x5EED_0013, |rng| {
        let spec = random_scenario(rng);
        let name = spec.name();
        let w = spec.lower().map_err(|e| format!("{name}: lower: {e}"))?;
        let gpu = rng.chance(0.5);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        let stock = Simulator::new(target);
        let mut edited = Simulator::new(target);
        if gpu {
            edited.edit_gpu(|g| g.freq_ghz *= 0.5);
        } else {
            edited.edit_cpu(|c| c.freq_ghz *= 0.5);
        }
        if stock.instance_key() == edited.instance_key() {
            return Err(format!("{name}: edited spec kept the stock instance key"));
        }
        let vocab = TransformKind::vocabulary(gpu);
        let mut s = Schedule::initial(Arc::new(w));
        for step in 0..(3 + rng.below(8)) {
            if let Ok(next) = apply(&s, *rng.choice(&vocab), rng, gpu) {
                s = next;
            }
            for (tag, sim) in [("stock", &stock), ("edited", &edited)] {
                let inc = sim.latency(&s);
                let full = sim.latency_full(&s);
                if inc.to_bits() != full.to_bits() {
                    return Err(format!(
                        "{name} ({target:?}) step {step} {tag}: memo-served \
                         {inc:e} != full recompute {full:e}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scenario_workloads_survive_transform_storms() {
    // scenario-lowered workloads are first-class search substrates: any
    // transform sequence keeps them valid with positive finite latency
    // (the same contract the hand-built benchmarks satisfy).
    check("scenario-transform-storm", 200, 0x5CE_A211, |rng| {
        let spec = random_scenario(rng);
        let w = spec.lower().map_err(|e| format!("lower: {e}"))?;
        let gpu = rng.chance(0.5);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        let base = Schedule::initial(Arc::new(w));
        let s = random_schedule(&base, 12, gpu, rng);
        s.validate().map_err(|e| format!("{}: invalid after storm: {e}", spec.name()))?;
        let lat = litecoop::sim::Simulator::new(target).latency(&s);
        if !(lat.is_finite() && lat > 0.0) {
            return Err(format!("{}: bad latency {lat}", spec.name()));
        }
        // trace keys stay usable (cache substrate for sweeps)
        let k1 = trace_key(&s, target);
        let k2 = trace_key(&s.clone(), target);
        if k1 != k2 {
            return Err(format!("{}: unstable trace key", spec.name()));
        }
        Ok(())
    });
}

#[test]
fn prop_reachable_schedules_lint_clean() {
    // the analyzer's reachability contract (see `litecoop::analysis`):
    // every schedule reachable through the Deny-gated `apply` — across
    // all six scenario families, both targets, and every intermediate
    // state of a transform storm — carries ZERO Deny-level diagnostics.
    // Warn-level diagnostics are allowed (degenerate-but-legal states
    // are deliberately reachable; `experiments lint_audit` counts them).
    use litecoop::analysis::{self, Severity};
    let mut families_seen = std::collections::BTreeSet::new();
    let mut targets_seen = std::collections::BTreeSet::new();
    check("reachable-lint-clean", 200, 0x11A7_0001, |rng| {
        let spec = random_scenario(rng);
        let name = spec.name();
        let w = spec.lower().map_err(|e| format!("{name}: lower: {e}"))?;
        families_seen.insert(name.split('@').next().unwrap_or("").to_string());
        let gpu = rng.chance(0.5);
        targets_seen.insert(gpu);
        let vocab = TransformKind::vocabulary(gpu);
        let mut s = Schedule::initial(Arc::new(w));
        for step in 0..(1 + rng.below(12)) {
            if let Ok(next) = apply(&s, *rng.choice(&vocab), rng, gpu) {
                s = next;
            }
            let denies: Vec<String> = analysis::analyze(&s, gpu)
                .into_iter()
                .filter(|d| d.severity == Severity::Deny)
                .map(|d| d.to_string())
                .collect();
            if !denies.is_empty() {
                return Err(format!(
                    "{name} (gpu={gpu}) step {step}: reachable schedule has \
                     Deny diagnostics: {denies:?}"
                ));
            }
            // the gate and the full analysis must agree
            if analysis::first_deny(&s, gpu).is_some() {
                return Err(format!(
                    "{name} (gpu={gpu}) step {step}: first_deny fired on a \
                     reachable schedule"
                ));
            }
        }
        Ok(())
    });
    assert_eq!(
        families_seen.len(),
        6,
        "all six scenario families must be exercised, saw {families_seen:?}"
    );
    assert_eq!(targets_seen.len(), 2, "both targets must be exercised");
}

#[test]
fn prop_tree_roundtrip_preserves_search() {
    // the tree-persistence contract (`litecoop::mcts::treestore`): for
    // random scenarios, budgets, seeds, model rosters, targets, and
    // engines (serial and tree-parallel) — checkpoint a search at a
    // random sample k, snapshot, resume from the snapshot with freshly
    // constructed process-local state, and run to budget N: the result
    // is bit-identical to the uninterrupted N-sample run, the resumed
    // tree re-snapshots byte-identically (save→load→save fixed point),
    // and every node in the resumed tree passes the static legality
    // analyzer (`analysis::first_deny` is None tree-wide).
    use litecoop::llm::registry::paper_config;
    use litecoop::llm::ModelSet;
    use litecoop::mcts::{Mcts, SearchConfig, SearchResult};
    use litecoop::sim::Simulator;

    fn diff(a: &SearchResult, b: &SearchResult) -> Result<(), String> {
        let checks: [(&str, bool); 14] = [
            ("workload", a.workload == b.workload),
            ("best_speedup", a.best_speedup.to_bits() == b.best_speedup.to_bits()),
            ("best_latency", a.best_latency_s.to_bits() == b.best_latency_s.to_bits()),
            (
                "baseline_latency",
                a.baseline_latency_s.to_bits() == b.baseline_latency_s.to_bits(),
            ),
            ("curve", a.curve == b.curve),
            ("compile_time", a.compile_time_s.to_bits() == b.compile_time_s.to_bits()),
            ("api_cost", a.api_cost_usd.to_bits() == b.api_cost_usd.to_bits()),
            ("n_samples", a.n_samples == b.n_samples),
            ("n_ca_events", a.n_ca_events == b.n_ca_events),
            ("n_errors", a.n_errors == b.n_errors),
            ("call_counts", a.call_counts == b.call_counts),
            ("eval_cache", a.eval_cache == b.eval_cache),
            ("lint_rejects", a.lint_rejects == b.lint_rejects),
            ("faults", a.faults == b.faults),
        ];
        if let Some((field, _)) = checks.iter().find(|(_, ok)| !ok) {
            return Err(format!("field '{field}' diverged after resume"));
        }
        if a.best_schedule.trace.running_hash() != b.best_schedule.trace.running_hash()
            || a.best_schedule.fingerprint() != b.best_schedule.fingerprint()
        {
            return Err("incumbent schedule diverged after resume".to_string());
        }
        Ok(())
    }

    check("tree-roundtrip-preserves-search", 200, 0x7EE_5701, |rng| {
        let spec = random_scenario(rng);
        let name = spec.name();
        let w = spec.lower().map_err(|e| format!("{name}: lower: {e}"))?;
        let root = Schedule::initial(Arc::new(w));
        let gpu = rng.chance(0.3);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        let budget = 6 + rng.below(30);
        let k = 1 + rng.below(budget - 1); // strictly inside the run
        let threads = if rng.chance(0.25) { 2 } else { 1 };
        let n_llms = 2 + rng.below(3);
        let seed = rng.next_u64();
        let cfg = SearchConfig {
            budget,
            seed,
            checkpoints: vec![budget / 2, budget],
            ..SearchConfig::default()
        };
        let models = || ModelSet::new(paper_config(n_llms, "gpt-5.2"));
        let engine =
            || Mcts::new(cfg.clone(), models(), Simulator::new(target), root.clone());

        let uninterrupted = if threads > 1 {
            engine().run_parallel(&name, threads)
        } else {
            engine().run(&name)
        };
        let part = if threads > 1 {
            engine().run_parallel_until(threads, k)
        } else {
            engine().run_until(k)
        };
        let snap = part.snapshot();
        let resumed = Mcts::resume(&snap, models(), Simulator::new(target), root.clone())
            .map_err(|e| format!("{name}: resume failed: {e}"))?;
        if let Some((i, d)) = resumed.first_tree_deny() {
            return Err(format!("{name}: resumed tree node {i} carries Deny: {d}"));
        }
        let resnap = resumed.snapshot();
        if format!("{snap}") != format!("{resnap}") {
            return Err(format!(
                "{name}: snapshot -> resume -> snapshot is not a fixed point \
                 (k={k}, budget={budget}, threads={threads})"
            ));
        }
        let continued = if threads > 1 {
            resumed.run_parallel(&name, threads)
        } else {
            resumed.run(&name)
        };
        diff(&uninterrupted, &continued).map_err(|e| {
            format!("{name} (k={k}, budget={budget}, threads={threads}, gpu={gpu}): {e}")
        })
    });
}

#[test]
fn prop_zero_rate_fault_plan_is_bit_identical_passthrough() {
    // the passthrough half of the fault-injection determinism contract
    // (`litecoop::llm::faults`): an installed FaultPlan whose rates are
    // all zero must be observationally ABSENT — for random scenarios,
    // budgets, seeds, rosters, targets, and engines (serial and
    // tree-parallel), the search with a zero-rate plan produces a
    // byte-identical snapshot and a bit-identical result to the search
    // with no plan at all. Zero-rate models never draw from the fault
    // stream, so not even the plan's private RNG position can leak into
    // the search; the only allowed difference is the plan object itself
    // (which the snapshot omits when `is_zero()`).
    use litecoop::llm::faults::{FaultPlan, FaultRates};
    use litecoop::llm::registry::paper_config;
    use litecoop::llm::ModelSet;
    use litecoop::mcts::{Mcts, SearchConfig};
    use litecoop::sim::Simulator;

    check("zero-rate-fault-passthrough", 200, 0xFA17_0001, |rng| {
        let spec = random_scenario(rng);
        let name = spec.name();
        let w = spec.lower().map_err(|e| format!("{name}: lower: {e}"))?;
        let root = Schedule::initial(Arc::new(w));
        let gpu = rng.chance(0.3);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        let budget = 5 + rng.below(20);
        let threads = if rng.chance(0.25) { 2 } else { 1 };
        let n_llms = 2 + rng.below(3);
        let seed = rng.next_u64();
        let plan_seed = rng.next_u64();
        let cfg = SearchConfig {
            budget,
            seed,
            checkpoints: vec![budget],
            ..SearchConfig::default()
        };
        // a zero-rate plan with a live, nonzero seed: the stream is armed
        // but must never be drawn from
        let zero_plan = FaultPlan::uniform(n_llms, FaultRates::uniform(0.0), plan_seed);
        if !zero_plan.is_zero() {
            return Err(format!("{name}: uniform(0.0) plan is not zero"));
        }
        let engine = |plan: Option<FaultPlan>| {
            let mut models = ModelSet::new(paper_config(n_llms, "gpt-5.2"));
            if let Some(p) = plan {
                models.set_fault_plan(p);
            }
            Mcts::new(cfg.clone(), models, Simulator::new(target), root.clone())
        };
        // `run` consumes the engine, so snapshots come from `run_until`
        // engines and results from separate (deterministic) `run` calls
        let snap_of = |plan: Option<FaultPlan>| {
            let done = if threads > 1 {
                engine(plan).run_parallel_until(threads, budget)
            } else {
                engine(plan).run_until(budget)
            };
            format!("{}", done.snapshot())
        };
        let result_of = |plan: Option<FaultPlan>| {
            if threads > 1 {
                engine(plan).run_parallel(&name, threads)
            } else {
                engine(plan).run(&name)
            }
        };
        let snap_clean = snap_of(None);
        let snap_plan = snap_of(Some(zero_plan.clone()));
        let r_clean = result_of(None);
        let r_plan = result_of(Some(zero_plan));
        if snap_clean != snap_plan {
            return Err(format!(
                "{name}: zero-rate plan perturbed the snapshot \
                 (budget={budget}, threads={threads}, plan_seed={plan_seed:#x})"
            ));
        }
        if r_clean.best_speedup.to_bits() != r_plan.best_speedup.to_bits()
            || r_clean.compile_time_s.to_bits() != r_plan.compile_time_s.to_bits()
            || r_clean.api_cost_usd.to_bits() != r_plan.api_cost_usd.to_bits()
            || r_clean.call_counts != r_plan.call_counts
            || r_clean.n_errors != r_plan.n_errors
        {
            return Err(format!("{name}: zero-rate plan perturbed the result"));
        }
        if !r_plan.faults.is_empty() {
            return Err(format!(
                "{name}: zero-rate plan reported injected faults: {}",
                r_plan.faults.summary()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_tree_merge_is_commutative_associative_and_resumable() {
    // the merge algebra (`litecoop::mcts::treemerge`): for random
    // scenarios, targets, budgets, and 3-lane seed sets drawn from the
    // distributed driver's own seed stream (`lane_seed`), the keyed-union
    // merge is commutative AND associative up to f64 bit equality of the
    // canonical re-serialization — visit counts, reward sums, and
    // per-model stat totals included, since the snapshot renders them at
    // bit precision. The merged snapshot → resume → snapshot loop is a
    // byte fixed point, and merged trees lint clean tree-wide. Lanes are
    // snapshotted once and every merge arrangement resumes from those
    // snapshots — the file-mediated protocol the fleet driver uses.
    use litecoop::llm::registry::paper_config;
    use litecoop::llm::ModelSet;
    use litecoop::mcts::treemerge::merge_engines;
    use litecoop::mcts::{Mcts, SearchConfig};
    use litecoop::runtime::driver::lane_seed;
    use litecoop::sim::Simulator;
    use litecoop::util::Json;

    check("tree-merge-algebra", 200, 0x3E26_E001, |rng| {
        let spec = random_scenario(rng);
        let name = spec.name();
        let w = spec.lower().map_err(|e| format!("{name}: lower: {e}"))?;
        let root = Schedule::initial(Arc::new(w));
        let gpu = rng.chance(0.25);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        let budget = 8 + rng.below(9);
        let case_seed = rng.next_u64();
        let seeds: Vec<u64> = (0..3).map(|i| lane_seed(case_seed, i)).collect();
        if seeds[0] == seeds[1] || seeds[0] == seeds[2] || seeds[1] == seeds[2] {
            return Ok(()); // ~2^-63 splitmix collision: not this property's bug
        }

        let models = || ModelSet::new(paper_config(2, "gpt-5.2"));
        let snaps: Vec<String> = seeds
            .iter()
            .map(|&seed| {
                let cfg = SearchConfig {
                    budget,
                    seed,
                    checkpoints: vec![budget],
                    ..SearchConfig::default()
                };
                let e = Mcts::new(cfg, models(), Simulator::new(target), root.clone())
                    .run_until(budget);
                format!("{}", e.snapshot())
            })
            .collect();
        let lane_at = |i: usize| -> Result<Mcts, String> {
            let v = Json::parse(&snaps[i]).map_err(|e| format!("{name}: lane {i}: {e}"))?;
            Mcts::resume(&v, models(), Simulator::new(target), root.clone())
                .map_err(|e| format!("{name}: lane {i} resume: {e}"))
        };
        let merge_of = |order: &[usize]| -> Result<String, String> {
            let lanes = order.iter().map(|&i| lane_at(i)).collect::<Result<Vec<_>, _>>()?;
            let merged = merge_engines(lanes).map_err(|e| format!("{name}: merge: {e}"))?;
            Ok(format!("{}", merged.snapshot()))
        };

        let canonical = merge_of(&[0, 1, 2])?;

        // commutativity: any lane order re-serializes identically
        let perms: [[usize; 3]; 5] =
            [[0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]];
        let perm = *rng.choice(&perms);
        if merge_of(&perm)? != canonical {
            return Err(format!("{name}: merge not commutative under order {perm:?}"));
        }

        // associativity: nested pairwise merges equal the flat 3-way one
        let left = {
            let inner = merge_engines(vec![lane_at(0)?, lane_at(1)?])
                .map_err(|e| format!("{name}: merge(0,1): {e}"))?;
            let outer = merge_engines(vec![inner, lane_at(2)?])
                .map_err(|e| format!("{name}: merge((0,1),2): {e}"))?;
            format!("{}", outer.snapshot())
        };
        if left != canonical {
            return Err(format!("{name}: merge((a,b),c) != merge(a,b,c)"));
        }
        let right = {
            let inner = merge_engines(vec![lane_at(1)?, lane_at(2)?])
                .map_err(|e| format!("{name}: merge(1,2): {e}"))?;
            let outer = merge_engines(vec![lane_at(0)?, inner])
                .map_err(|e| format!("{name}: merge(0,(1,2)): {e}"))?;
            format!("{}", outer.snapshot())
        };
        if right != canonical {
            return Err(format!("{name}: merge(a,(b,c)) != merge(a,b,c)"));
        }

        // merged snapshot -> resume -> snapshot is a byte fixed point,
        // and the merged tree lints clean on every node
        let v = Json::parse(&canonical).map_err(|e| format!("{name}: reparse: {e}"))?;
        let resumed = Mcts::resume(&v, models(), Simulator::new(target), root.clone())
            .map_err(|e| format!("{name}: merged resume: {e}"))?;
        if let Some((i, d)) = resumed.first_tree_deny() {
            return Err(format!("{name}: merged tree node {i} carries Deny: {d}"));
        }
        if format!("{}", resumed.snapshot()) != canonical {
            return Err(format!(
                "{name}: merged snapshot -> resume -> snapshot drifted \
                 (budget={budget}, seeds={seeds:?})"
            ));
        }
        Ok(())
    });
}

// ------------------------------------------------------------------ harness

#[test]
fn harness_reports_failing_seed_for_replay() {
    // the replay contract itself: a failing property must surface its
    // case seed in the panic message
    let err = std::panic::catch_unwind(|| {
        check("always-fails", 5, 0xBAD, |_| Err("boom".into()));
    })
    .expect_err("property must fail");
    let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
    assert!(msg.contains("always-fails"), "{msg}");
    assert!(msg.contains("seed 0x"), "{msg}");
    assert!(msg.contains("replay:"), "{msg}");
    // and the quoted seed is the real splitmix64 stream head
    let mut st = 0xBADu64;
    let first = splitmix64(&mut st);
    assert!(msg.contains(&format!("{first:#018x}")), "{msg}");
}

#[test]
fn harness_stats_sanity() {
    // merged empty stats stay 0.0 (satellite audit of CacheStats::merge)
    let mut s = CacheStats::default();
    s.merge(&CacheStats::default());
    assert_eq!(s.hit_rate(), 0.0);
}

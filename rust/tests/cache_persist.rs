//! Persistent eval-cache warm-start integration tests: save→load
//! round-trips, degradation on corrupt files, equivalence of file-backed
//! and in-process cache sharing, and the two-process sweep contract
//! (run 1 saves, run 2 loads, reports hits, and reproduces run 1's
//! results byte-identically).

use litecoop::coordinator::{self, RunSpec, Searcher};
use litecoop::llm::registry::paper_config;
use litecoop::llm::ModelSet;
use litecoop::mcts::evalcache::EvalCache;
use litecoop::mcts::{Mcts, SearchConfig, SearchResult};
use litecoop::runtime::driver;
use litecoop::schedule::Schedule;
use litecoop::sim::{Simulator, Target};
use litecoop::workloads;
use std::sync::Arc;

/// Unique temp path per test (tests run concurrently in one process).
fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("litecoop_cache_persist_{tag}_{}.json", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

fn search_cfg(seed: u64) -> SearchConfig {
    SearchConfig {
        budget: 120,
        seed,
        checkpoints: vec![60, 120],
        ..SearchConfig::default()
    }
}

fn engine(cache: EvalCache, seed: u64) -> Mcts {
    let sched = Schedule::initial(Arc::new(workloads::gemm::gemm(512, 512, 512)));
    let models = ModelSet::new(paper_config(4, "gpt-5.2"));
    Mcts::with_cache(search_cfg(seed), models, Simulator::new(Target::Cpu), sched, cache)
}

/// The "byte-identical results" contract: everything except
/// `compile_time_s`, which is *honestly* lower on warm runs because
/// cache-served measurements charge no harness overhead.
fn assert_same_outcome(a: &SearchResult, b: &SearchResult) {
    assert_eq!(a.workload, b.workload);
    assert_eq!(a.best_speedup.to_bits(), b.best_speedup.to_bits());
    assert_eq!(a.best_latency_s.to_bits(), b.best_latency_s.to_bits());
    assert_eq!(a.baseline_latency_s.to_bits(), b.baseline_latency_s.to_bits());
    assert_eq!(a.curve, b.curve);
    assert_eq!(a.api_cost_usd, b.api_cost_usd);
    assert_eq!(a.n_samples, b.n_samples);
    assert_eq!(a.n_ca_events, b.n_ca_events);
    assert_eq!(a.call_counts, b.call_counts);
}

#[test]
fn save_load_roundtrip_is_lossless_through_a_real_search() {
    let path = tmp_path("roundtrip");
    let (_, cache) = engine(EvalCache::with_capacity(50_000), 3).run_with_cache("gemm");
    let entries = cache.len();
    assert!(entries > 0);
    cache.save_file(&path).unwrap();
    let loaded = EvalCache::load_file(&path).unwrap();
    // capacity bound survives, counters start at zero, every
    // ground-truth entry survives (predictions are per-process and
    // dropped — the loaded count can only be lower by the pred count)
    assert_eq!(loaded.capacity(), 50_000);
    assert_eq!(loaded.stats().hits + loaded.stats().misses, 0);
    assert!(loaded.len() <= entries);
    assert!(!loaded.is_empty());
    // saving the loaded cache reproduces the file byte-for-byte
    // (deterministic serialization: sorted keys, exact f64 rendering)
    let first = std::fs::read_to_string(&path).unwrap();
    loaded.save_file(&path).unwrap();
    assert_eq!(std::fs::read_to_string(&path).unwrap(), first);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn warm_start_from_file_matches_in_process_shared_cache() {
    let path = tmp_path("file_vs_mem");
    let (cold, cache) = engine(EvalCache::new(), 9).run_with_cache("gemm");
    cache.save_file(&path).unwrap();

    // path A: share the warmed cache in-process (PR-1 mechanism)
    let (warm_mem, _) = engine(cache, 9).run_with_cache("gemm");
    // path B: round-trip the cache through the file
    let from_file = EvalCache::load_file(&path).unwrap();
    let (warm_file, _) = engine(from_file, 9).run_with_cache("gemm");

    // both warm runs report reuse and reproduce the cold outcome
    assert!(warm_file.eval_cache.hits > cold.eval_cache.hits);
    assert!(warm_file.eval_cache.hit_rate() > 0.0);
    assert_same_outcome(&cold, &warm_file);
    // the file round-trip is observationally identical to in-process
    // sharing — including counters and (warm) compile time
    assert_same_outcome(&warm_mem, &warm_file);
    assert_eq!(warm_mem.eval_cache, warm_file.eval_cache);
    assert_eq!(
        warm_mem.compile_time_s.to_bits(),
        warm_file.compile_time_s.to_bits()
    );
    assert!(warm_file.compile_time_s < cold.compile_time_s);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_or_truncated_file_degrades_to_cold_without_panicking() {
    let path = tmp_path("corrupt");
    let (_, cache) = engine(EvalCache::new(), 5).run_with_cache("gemm");
    cache.save_file(&path).unwrap();
    let valid = std::fs::read_to_string(&path).unwrap();

    for (tag, content) in [
        ("garbage", "not json at all {{{".to_string()),
        ("truncated", valid[..valid.len() / 2].to_string()),
        ("empty", String::new()),
        ("wrong_version", "{\"version\": 99, \"max_entries\": \"4\", \"lat\": {}}".to_string()),
        ("wrong_shape", "[1, 2, 3]".to_string()),
    ] {
        std::fs::write(&path, &content).unwrap();
        assert!(EvalCache::load_file(&path).is_err(), "{tag} accepted");
        let cold = EvalCache::load_file_or_cold(&path);
        assert!(cold.is_empty(), "{tag} not cold");
        // a search seeded from the degraded cache still runs normally
        let (r, _) = engine(cold, 5).run_with_cache("gemm");
        assert!(r.best_speedup >= 1.0);
    }
    let _ = std::fs::remove_file(&path);
}

/// The ISSUE acceptance criterion: a two-process warm-start sweep —
/// save the cache in run 1, load it in run 2 on overlapping scenarios —
/// reports a nonzero (and strictly increased) hit rate in run 2 and
/// produces results byte-identical to a cold run. The two driver
/// invocations here share state only through the cache file, exactly
/// like two OS processes would.
#[test]
fn two_process_sweep_warm_start_acceptance() {
    let path = tmp_path("two_process");
    let _ = std::fs::remove_file(&path);
    let grid = workloads::scenarios::ScenarioGrid::parse("gemm", "m=128,256;k=128").unwrap();
    let searcher = Searcher::Coop {
        n: 2,
        largest: "gpt-5.2".into(),
    };
    let specs: Vec<RunSpec> =
        coordinator::sweep_specs(&grid.expand().unwrap(), &[Target::Cpu], &searcher, 60, 11, 1);
    assert_eq!(specs.len(), 2);

    // "process" 1: cold start, saves the cache file
    let run1 = driver::run_specs_cached(&specs, 2, Some(path.as_str()));
    assert!(std::path::Path::new(&path).exists(), "cache file not saved");
    // "process" 2: loads the file; must report strictly more hits and
    // reproduce the cold results
    let run2 = driver::run_specs_cached(&specs, 2, Some(path.as_str()));
    // control: a fully cold run with no file
    let cold = driver::run_specs(&specs, 2);

    for ((r1, r2), c) in run1.iter().zip(&run2).zip(&cold) {
        assert_same_outcome(r1, r2);
        assert_same_outcome(c, r2);
        assert!(
            r2.eval_cache.hits > r1.eval_cache.hits,
            "run 2 did not warm-start: {:?} vs {:?}",
            r2.eval_cache,
            r1.eval_cache
        );
        assert!(r2.eval_cache.misses < r1.eval_cache.misses);
        assert!(r2.eval_cache.hit_rate() > 0.0);
        assert_eq!(r1.eval_cache, c.eval_cache);
    }
    let _ = std::fs::remove_file(&path);
}

/// Scenario names flow through RunSpec/driver/cache keys: two different
/// scenario points of one family never share cache entries, the same
/// point always does.
#[test]
fn scenario_identity_keys_the_persistent_cache() {
    let path = tmp_path("identity");
    let _ = std::fs::remove_file(&path);
    let searcher = Searcher::Coop {
        n: 2,
        largest: "gpt-5.2".into(),
    };
    let spec_a = RunSpec::new("gemm@k=64,m=64,n=64", Target::Cpu, searcher.clone(), 40, 3);
    let spec_b = RunSpec::new("gemm@k=64,m=128,n=64", Target::Cpu, searcher, 40, 3);

    // run A twice through the file: second run must hit
    let a1 = driver::run_specs_cached(std::slice::from_ref(&spec_a), 1, Some(path.as_str()));
    let a2 = driver::run_specs_cached(std::slice::from_ref(&spec_a), 1, Some(path.as_str()));
    assert!(a2[0].eval_cache.hits > a1[0].eval_cache.hits);

    // a *different* scenario point sees no cross-contamination: same
    // counters as its own cold run (workload name is folded into every
    // cache key)
    let b_warmfile = driver::run_specs_cached(std::slice::from_ref(&spec_b), 1, Some(path.as_str()));
    let b_cold = driver::run_specs(std::slice::from_ref(&spec_b), 1);
    assert_eq!(b_warmfile[0].eval_cache, b_cold[0].eval_cache);
    assert_same_outcome(&b_warmfile[0], &b_cold[0]);
    let _ = std::fs::remove_file(&path);
}

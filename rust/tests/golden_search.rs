//! Golden-snapshot determinism tests.
//!
//! A fixed-seed `run_one` on each built-in workload (plus two scenario
//! points, exercising the `family@key=val` path) must reproduce the
//! exact final speedup (bit pattern) and the incumbent schedule's trace
//! hash + structural fingerprint recorded in the checked-in golden file.
//! The existing "serial == parallel" transparency tests can only catch
//! *relative* divergence; these snapshots catch silent RNG-stream drift
//! — a reordered draw, an extra consumed sample, a changed tie-break —
//! that shifts every configuration in lockstep.
//!
//! Lifecycle (insta-style self-bootstrap): if the golden file is
//! missing, or its `golden_version` differs from [`GOLDEN_VERSION`]
//! (i.e. the snapshot *spec* itself changed), the test writes the
//! current values and passes with a note — **commit the generated
//! file**. Otherwise any mismatch fails with a drift report; if the
//! drift is an intentional behavior change, delete the file (or bump
//! [`GOLDEN_VERSION`]), rerun to regenerate, and commit the update
//! alongside the change that caused it.

use litecoop::coordinator::{run_many, RunSpec, Searcher};
use litecoop::mcts::SearchResult;
use litecoop::sim::Target;
use litecoop::util::json::Json;

const GOLDEN_PATH: &str = "rust/tests/goldens/search_goldens.json";
const GOLDEN_DIR: &str = "rust/tests/goldens";

/// Bump when the snapshot spec below (workload list, budget, seed,
/// searcher) changes — stale goldens then regenerate instead of
/// reporting phantom drift.
const GOLDEN_VERSION: f64 = 1.0;
const BUDGET: usize = 60;
const SEED: u64 = 7;

/// Every registry workload plus two scenario-grammar points.
const WORKLOADS: [&str; 8] = [
    "llama3_attention",
    "deepseek_moe",
    "flux_attention",
    "flux_conv",
    "llama4_mlp",
    "gemm",
    "gemm@batch=2,k=256,m=256,n=256",
    "attention@head_dim=32,heads=4,seq=256",
];

fn snapshot_specs() -> Vec<RunSpec> {
    WORKLOADS
        .iter()
        .map(|w| {
            RunSpec::new(
                w,
                Target::Cpu,
                Searcher::Coop {
                    n: 2,
                    largest: "gpt-5.2".into(),
                },
                BUDGET,
                SEED,
            )
        })
        .collect()
}

fn snapshot_entry(r: &SearchResult) -> Json {
    let mut e = Json::obj();
    e.set("speedup", r.best_speedup.into()) // human-readable
        .set("speedup_bits", r.best_speedup.to_bits().to_string().into())
        .set(
            "trace_hash",
            r.best_schedule.trace.running_hash().to_string().into(),
        )
        .set(
            "fingerprint",
            r.best_schedule.fingerprint().to_string().into(),
        )
        .set("n_samples", r.n_samples.into());
    e
}

fn write_goldens(entries: &Json) {
    std::fs::create_dir_all(GOLDEN_DIR).expect("create goldens dir");
    let mut root = Json::obj();
    root.set("golden_version", GOLDEN_VERSION.into())
        .set("budget", BUDGET.into())
        .set("seed", (SEED as usize).into())
        .set("entries", entries.clone());
    std::fs::write(GOLDEN_PATH, format!("{root}\n")).expect("write goldens");
}

#[test]
fn golden_search_snapshots() {
    let specs = snapshot_specs();
    let results = run_many(&specs, 4);
    let mut entries = Json::obj();
    for (sp, r) in specs.iter().zip(&results) {
        assert_eq!(&r.workload, &sp.workload);
        entries.set(&sp.workload, snapshot_entry(r));
    }

    if !std::path::Path::new(GOLDEN_PATH).exists() {
        write_goldens(&entries);
        eprintln!(
            "golden_search: no golden file found — generated {GOLDEN_PATH}; \
             commit it to lock the current RNG streams in"
        );
        return;
    }
    // a present-but-unparseable file is damage, not a bootstrap case:
    // regenerating from the current (possibly already-drifted) streams
    // would silently disable the drift gate
    let recorded = Json::parse_file(GOLDEN_PATH).unwrap_or_else(|e| {
        panic!(
            "{GOLDEN_PATH} exists but is unreadable ({e}); restore it from git, \
             or delete it and rerun to regenerate from scratch"
        )
    });
    if recorded.get("golden_version").and_then(Json::as_f64) != Some(GOLDEN_VERSION) {
        write_goldens(&entries);
        eprintln!(
            "golden_search: golden file was for an older snapshot spec — \
             regenerated {GOLDEN_PATH}; commit the update"
        );
        return;
    }

    let golden_entries = recorded
        .get("entries")
        .and_then(Json::as_obj)
        .unwrap_or_else(|| panic!("{GOLDEN_PATH}: malformed (no entries); delete and rerun"));
    let mut drift = Vec::new();
    for (sp, r) in specs.iter().zip(&results) {
        let Some(g) = golden_entries.get(&sp.workload) else {
            drift.push(format!("{}: missing from goldens", sp.workload));
            continue;
        };
        let field = |key: &str| {
            g.get(key)
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or_default()
        };
        let num = |key: &str| g.get(key).and_then(Json::as_f64).unwrap_or(-1.0);
        let got_bits = r.best_speedup.to_bits().to_string();
        if field("speedup_bits") != got_bits {
            drift.push(format!(
                "{}: final speedup drifted (golden {} = {}, got {} = {})",
                sp.workload,
                field("speedup_bits"),
                num("speedup"),
                got_bits,
                r.best_speedup
            ));
        }
        let got_trace = r.best_schedule.trace.running_hash().to_string();
        if field("trace_hash") != got_trace {
            drift.push(format!(
                "{}: incumbent trace hash drifted (golden {}, got {got_trace})",
                sp.workload,
                field("trace_hash")
            ));
        }
        let got_fp = r.best_schedule.fingerprint().to_string();
        if field("fingerprint") != got_fp {
            drift.push(format!(
                "{}: incumbent fingerprint drifted (golden {}, got {got_fp})",
                sp.workload,
                field("fingerprint")
            ));
        }
        if num("n_samples") != r.n_samples as f64 {
            drift.push(format!(
                "{}: sample count drifted (golden {}, got {})",
                sp.workload,
                num("n_samples"),
                r.n_samples
            ));
        }
    }
    assert!(
        drift.is_empty(),
        "RNG-stream / determinism drift against {GOLDEN_PATH}:\n  {}\n\
         If this change is intentional, delete the golden file (or bump \
         GOLDEN_VERSION), rerun `cargo test --test golden_search`, and \
         commit the regenerated goldens with the change that caused it.",
        drift.join("\n  ")
    );
}

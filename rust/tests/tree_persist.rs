//! Versioned MCTS tree persistence integration tests: the
//! resume-equivalence contract (checkpoint at sample k, resume from
//! disk, run to budget N — bit-identical to an uninterrupted N-sample
//! run), save→load→save byte-identity, and the corruption suite (every
//! malformed tree file degrades to a cold search, never a panic).
//!
//! Mirrors `cache_persist.rs` for the eval-cache layer; this file locks
//! the tree layer above it (`litecoop::mcts::treestore`).

use litecoop::llm::registry::paper_config;
use litecoop::llm::ModelSet;
use litecoop::mcts::{Mcts, SearchConfig, SearchResult};
use litecoop::schedule::Schedule;
use litecoop::sim::{Simulator, Target};
use litecoop::util::json::f64_to_bits_json;
use litecoop::util::Json;
use litecoop::workloads;
use std::sync::Arc;

/// Unique temp path per test (tests run concurrently in one process).
fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("litecoop_tree_persist_{tag}_{}.json", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

fn search_cfg(budget: usize, seed: u64) -> SearchConfig {
    SearchConfig {
        budget,
        seed,
        checkpoints: vec![budget / 2, budget],
        ..SearchConfig::default()
    }
}

/// The process-local pieces a snapshot cannot carry — what a resuming
/// process must reconstruct itself before calling [`Mcts::resume`].
fn fresh_parts(workload: &str) -> (ModelSet, Simulator, Schedule) {
    let w = workloads::resolve(workload).unwrap();
    (
        ModelSet::new(paper_config(4, "gpt-5.2")),
        Simulator::new(Target::Cpu),
        Schedule::initial(Arc::new(w)),
    )
}

fn engine_for(workload: &str, budget: usize, seed: u64) -> Mcts {
    let (models, sim, root) = fresh_parts(workload);
    Mcts::new(search_cfg(budget, seed), models, sim, root)
}

/// Full bit-equality of two search reports — unlike the warm-cache
/// contract in `cache_persist.rs` this includes `compile_time_s`,
/// `eval_cache`, and `lint_rejects`: a resumed tree restores the model
/// latency accounting, the cache counters, and the running analyzer
/// tally, so nothing is allowed to drift.
fn assert_bit_identical(a: &SearchResult, b: &SearchResult) {
    assert_eq!(a.workload, b.workload);
    assert_eq!(a.best_speedup.to_bits(), b.best_speedup.to_bits());
    assert_eq!(a.best_latency_s.to_bits(), b.best_latency_s.to_bits());
    assert_eq!(a.baseline_latency_s.to_bits(), b.baseline_latency_s.to_bits());
    assert_eq!(a.curve, b.curve);
    assert_eq!(a.compile_time_s.to_bits(), b.compile_time_s.to_bits());
    assert_eq!(a.api_cost_usd.to_bits(), b.api_cost_usd.to_bits());
    assert_eq!(a.n_samples, b.n_samples);
    assert_eq!(a.n_ca_events, b.n_ca_events);
    assert_eq!(a.n_errors, b.n_errors);
    assert_eq!(a.call_counts, b.call_counts);
    assert_eq!(a.eval_cache, b.eval_cache);
    assert_eq!(a.lint_rejects, b.lint_rejects);
    assert_eq!(
        a.best_schedule.trace.running_hash(),
        b.best_schedule.trace.running_hash()
    );
    assert_eq!(a.best_schedule.fingerprint(), b.best_schedule.fingerprint());
}

// ------------------------------------------------------- resume equivalence

#[test]
fn serial_resume_from_disk_is_bit_identical_to_uninterrupted() {
    // save at sample k, resume in a "new process" (fresh models, sim,
    // root), run to budget N: identical to the uninterrupted N-run —
    // and to just continuing the checkpointed engine in-process.
    for workload in ["gemm", "llama3_attention"] {
        let path = tmp_path(&format!("serial_{workload}"));
        let uninterrupted = engine_for(workload, 96, 13).run(workload);

        let part = engine_for(workload, 96, 13).run_until(40);
        assert_eq!(part.samples(), 40);
        part.save_file(&path).unwrap();

        let (models, sim, root) = fresh_parts(workload);
        let resumed = Mcts::load_file(&path, models, sim, root).unwrap();
        assert_eq!(resumed.samples(), 40);
        let from_disk = resumed.run(workload);
        assert_bit_identical(&uninterrupted, &from_disk);

        // the checkpointed engine itself continues identically too
        let in_process = part.run(workload);
        assert_bit_identical(&uninterrupted, &in_process);

        assert_eq!(uninterrupted.n_samples, 96);
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn parallel_resume_from_disk_is_bit_identical_to_uninterrupted() {
    // the same contract for the tree-parallel engine: checkpoints land
    // on round boundaries (no in-flight marks), and a resumed search
    // replays the identical per-round lane-seed sequence.
    for workload in ["gemm", "llama3_attention"] {
        let path = tmp_path(&format!("parallel_{workload}"));
        let uninterrupted = engine_for(workload, 64, 9).run_parallel(workload, 4);

        let part = engine_for(workload, 64, 9).run_parallel_until(4, 24);
        assert!(part.samples() >= 24, "stopped short: {}", part.samples());
        assert!(part.samples() < 64, "ran past the checkpoint");
        part.save_file(&path).unwrap();

        let (models, sim, root) = fresh_parts(workload);
        let resumed = Mcts::load_file(&path, models, sim, root).unwrap();
        let from_disk = resumed.run_parallel(workload, 4);
        assert_bit_identical(&uninterrupted, &from_disk);
        assert_eq!(uninterrupted.n_samples, 64);
        std::fs::remove_file(&path).ok();
    }
}

// ------------------------------------------------------------- round trips

#[test]
fn save_load_save_is_byte_identical_and_skips_rendered_artifacts() {
    let path_a = tmp_path("roundtrip_a");
    let path_b = tmp_path("roundtrip_b");
    let part = engine_for("gemm", 80, 5).run_until(48);
    part.save_file(&path_a).unwrap();

    let (models, sim, root) = fresh_parts("gemm");
    let loaded = Mcts::load_file(&path_a, models, sim, root).unwrap();
    assert_eq!(loaded.samples(), 48);
    // a tree rebuilt from disk passes the full static legality analyzer
    // on every node — nothing illegal was smuggled in by deserialization
    assert_eq!(loaded.first_tree_deny(), None);
    loaded.save_file(&path_b).unwrap();

    // deterministic serialization: sorted keys, exact bit-level f64
    // rendering — the second save reproduces the first byte-for-byte
    let first = std::fs::read_to_string(&path_a).unwrap();
    let second = std::fs::read_to_string(&path_b).unwrap();
    assert_eq!(first, second, "save -> load -> save drifted");

    // rendered code and trace tails are derived artifacts: re-rendered
    // lazily on demand, never serialized
    let snap = Json::parse(&first).unwrap();
    let nodes = snap.get("nodes").and_then(Json::as_arr).unwrap();
    assert!(nodes.len() > 1, "search grew no tree");
    for n in nodes {
        assert!(n.get("code").is_none(), "rendered code was persisted");
        assert!(n.get("trace_tail").is_none(), "trace tail was persisted");
    }
    std::fs::remove_file(&path_a).ok();
    std::fs::remove_file(&path_b).ok();
}

#[test]
fn missing_file_starts_cold_silently() {
    let path = tmp_path("no_such_file");
    std::fs::remove_file(&path).ok();
    let (models, sim, root) = fresh_parts("gemm");
    let (engine, resumed) =
        Mcts::resume_file_or_cold(&path, search_cfg(16, 3), models, sim, root);
    assert!(!resumed);
    assert_eq!(engine.samples(), 0);
}

// --------------------------------------------------------- corruption suite

/// Every corrupt variant of a valid tree file must (a) surface an error
/// from the strict loader and (b) degrade to a cold tree through the
/// serving loader — never a panic, never a half-resumed tree.
#[test]
fn corrupt_tree_files_degrade_to_cold_never_panic() {
    let path = tmp_path("corrupt");
    let part = engine_for("gemm", 48, 21).run_until(30);
    part.save_file(&path).unwrap();
    let valid = std::fs::read_to_string(&path).unwrap();
    let n_nodes = Json::parse(&valid)
        .unwrap()
        .get("nodes")
        .and_then(Json::as_arr)
        .unwrap()
        .len();
    assert!(n_nodes > 1, "need a non-trivial tree to corrupt");

    // structured surgery on the parsed snapshot, re-serialized to text
    let mutate = |f: &dyn Fn(&mut Json)| {
        let mut v = Json::parse(&valid).unwrap();
        f(&mut v);
        format!("{v}")
    };
    let mutate_node = |i: usize, key: &'static str, val: Json| {
        mutate(&|v: &mut Json| {
            if let Json::Obj(m) = v {
                if let Some(Json::Arr(nodes)) = m.get_mut("nodes") {
                    nodes[i].set(key, val.clone());
                }
            }
        })
    };

    let cases: Vec<(&str, String)> = vec![
        ("truncated file", valid[..valid.len() / 2].to_string()),
        ("not json", "this is not { json".to_string()),
        (
            "unsupported version",
            mutate(&|v| {
                v.set("version", Json::Num(99.0));
            }),
        ),
        (
            "missing rng field",
            mutate(&|v| {
                if let Json::Obj(m) = v {
                    m.remove("rng");
                }
            }),
        ),
        (
            "dangling parent index",
            mutate_node(1, "parent", Json::Num(1_000_000.0)),
        ),
        (
            "non-finite visit count",
            mutate_node(1, "visits", f64_to_bits_json(f64::NAN)),
        ),
        (
            "non-array nodes",
            mutate(&|v| {
                v.set("nodes", Json::Str("gone".into()));
            }),
        ),
    ];

    for (what, text) in cases {
        std::fs::write(&path, text).unwrap();
        let (models, sim, root) = fresh_parts("gemm");
        let err = Mcts::load_file(&path, models, sim, root)
            .err()
            .unwrap_or_else(|| panic!("strict load accepted a tree file with {what}"));
        assert!(!err.is_empty(), "{what}: empty error message");

        // the serving path: warn + cold, and the cold engine still works
        let (models, sim, root) = fresh_parts("gemm");
        let (engine, resumed) =
            Mcts::resume_file_or_cold(&path, search_cfg(12, 2), models, sim, root);
        assert!(!resumed, "{what}: corrupt file was reported as resumed");
        assert_eq!(engine.samples(), 0, "{what}: cold tree is not cold");
    }

    // a cold-started engine after corruption is a fully working search
    let (models, sim, root) = fresh_parts("gemm");
    let (engine, _) = Mcts::resume_file_or_cold(&path, search_cfg(12, 2), models, sim, root);
    let r = engine.run("gemm");
    assert_eq!(r.n_samples, 12);
    assert!(r.best_speedup >= 1.0);
    std::fs::remove_file(&path).ok();
}

/// Resuming against the wrong process-local pieces is refused with a
/// clear error: wrong workload, wrong target, wrong model roster, or a
/// non-initial root schedule.
#[test]
fn resume_refuses_mismatched_process_state() {
    let path = tmp_path("mismatch");
    let part = engine_for("gemm", 32, 17).run_until(20);
    part.save_file(&path).unwrap();

    // wrong workload
    let (models, sim, root) = fresh_parts("llama3_attention");
    assert!(Mcts::load_file(&path, models, sim, root).is_err());

    // wrong target
    let (models, _, root) = fresh_parts("gemm");
    assert!(Mcts::load_file(&path, models, Simulator::new(Target::Gpu), root).is_err());

    // wrong model roster (2 models persisted as 4)
    let (_, sim, root) = fresh_parts("gemm");
    let small = ModelSet::new(paper_config(2, "gpt-5.2"));
    assert!(Mcts::load_file(&path, small, sim, root).is_err());

    // root that already carries trace steps is not an initial schedule
    let (models, sim, _) = fresh_parts("gemm");
    let traced = part.incumbent().clone();
    if !traced.trace.is_empty() {
        assert!(Mcts::load_file(&path, models, sim, traced).is_err());
    }
    std::fs::remove_file(&path).ok();
}

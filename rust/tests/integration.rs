//! Cross-module integration tests: search-over-simulator end-to-end,
//! paper-shape invariants, coordinator matrices, and property-based
//! storms over the full transform → simulate → featurize → predict path.

use litecoop::baselines;
use litecoop::benchutil::check_prop;
use litecoop::coordinator::{self, RunSpec, Searcher};
use litecoop::costmodel::{features, CostModel};
use litecoop::mcts::SearchConfig;
use litecoop::schedule::transforms::{apply, apply_sequence, TransformKind};
use litecoop::schedule::Schedule;
use litecoop::sim::{Simulator, Target};
use litecoop::util::Rng;
use litecoop::workloads;
use std::sync::Arc;

fn cfg(budget: usize, seed: u64) -> SearchConfig {
    SearchConfig {
        budget,
        seed,
        ..SearchConfig::default()
    }
}

#[test]
fn coop_beats_or_matches_single_small_model() {
    // a single small model should not dominate the 8-model collaboration
    let root = Schedule::initial(Arc::new(workloads::gemm::gemm(512, 512, 512)));
    let mut coop_sum = 0.0;
    let mut mini_sum = 0.0;
    for seed in 0..3 {
        coop_sum += baselines::litecoop(
            8,
            "gpt-5.2",
            Target::Cpu,
            root.clone(),
            cfg(100, seed),
            "gemm",
        )
        .best_speedup;
        mini_sum += baselines::single_llm(
            "gpt-5-mini",
            Target::Cpu,
            root.clone(),
            cfg(100, seed),
            "gemm",
        )
        .best_speedup;
    }
    assert!(
        coop_sum > mini_sum * 0.9,
        "coop {coop_sum} vs mini {mini_sum}"
    );
}

#[test]
fn coop_is_cheaper_than_single_large() {
    let root = Schedule::initial(Arc::new(workloads::mlp::llama4_mlp()));
    let single = baselines::single_llm(
        "gpt-5.2",
        Target::Cpu,
        root.clone(),
        cfg(120, 1),
        "llama4_mlp",
    );
    let coop = baselines::litecoop(8, "gpt-5.2", Target::Cpu, root, cfg(120, 1), "llama4_mlp");
    assert!(
        coop.api_cost_usd < single.api_cost_usd,
        "coop ${} !< single ${}",
        coop.api_cost_usd,
        single.api_cost_usd
    );
    assert!(
        coop.compile_time_s < single.compile_time_s,
        "coop {}s !< single {}s",
        coop.compile_time_s,
        single.compile_time_s
    );
}

#[test]
fn largest_model_share_drops_with_pool_size() {
    let root = Schedule::initial(Arc::new(workloads::moe::deepseek_moe()));
    let share = |n: usize| {
        // average over seeds: single runs are noisy
        (0..4)
            .map(|seed| {
                let r = baselines::litecoop(
                    n,
                    "gpt-5.2",
                    Target::Cpu,
                    root.clone(),
                    cfg(150, seed),
                    "moe",
                );
                let (reg, ca) = r.invocation_rate("gpt-5.2");
                reg + ca
            })
            .sum::<f64>()
            / 4.0
    };
    let s2 = share(2);
    let s8 = share(8);
    assert!(s8 < s2, "8-LLM largest share {s8} !< 2-LLM {s2}");
}

#[test]
fn every_paper_benchmark_searchable_on_both_targets() {
    for target in [Target::Cpu, Target::Gpu] {
        for w in workloads::paper_benchmarks() {
            let name = w.name.clone();
            let root = Schedule::initial(Arc::new(w));
            let r = baselines::litecoop(2, "gpt-5.2", target, root, cfg(40, 5), &name);
            assert!(
                r.best_speedup >= 1.0,
                "{name} on {target:?}: {}",
                r.best_speedup
            );
            assert!(r.best_schedule.validate().is_ok());
        }
    }
}

#[test]
fn coordinator_matrix_deterministic_across_thread_counts() {
    let specs: Vec<RunSpec> = (0..4)
        .map(|i| {
            RunSpec::new(
                "gemm",
                Target::Cpu,
                Searcher::Coop {
                    n: 4,
                    largest: "gpt-5.2".into(),
                },
                40,
                i,
            )
        })
        .collect();
    let a = coordinator::run_many(&specs, 1);
    let b = coordinator::run_many(&specs, 4);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.best_speedup, y.best_speedup);
    }
}

#[test]
fn driver_multi_workload_parallel_matches_serial() {
    use litecoop::runtime::driver;
    let searcher = Searcher::Coop {
        n: 2,
        largest: "gpt-5.2".into(),
    };
    let names = ["gemm", "llama4_mlp"];
    let par = driver::search_workloads(&names, Target::Cpu, &searcher, 40, 3, 4);
    let ser = driver::search_workloads(&names, Target::Cpu, &searcher, 40, 3, 1);
    for (x, y) in par.iter().zip(&ser) {
        assert_eq!(x.workload, y.workload);
        assert_eq!(x.best_speedup, y.best_speedup);
        assert_eq!(x.curve, y.curve);
        assert_eq!(x.api_cost_usd, y.api_cost_usd);
        assert_eq!(x.eval_cache, y.eval_cache);
    }
    // per-lane seeds are independent and deterministic; every search
    // consulted the evaluation cache
    assert_eq!(par[0].workload, "gemm");
    assert_eq!(par[1].workload, "llama4_mlp");
    assert!(par
        .iter()
        .all(|r| r.eval_cache.hits + r.eval_cache.misses > 0));
}

#[test]
fn prop_transform_storm_preserves_semantics_invariants() {
    // any sequence of transforms keeps: valid schedule, positive finite
    // latency on both targets, finite features
    check_prop("transform-storm", 30, 0xBEEF, |rng| {
        let w = workloads::paper_benchmarks()
            .into_iter()
            .nth(rng.below(5))
            .unwrap();
        let gpu = rng.chance(0.5);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        let sim = Simulator::new(target);
        let mut s = Schedule::initial(Arc::new(w));
        let vocab = TransformKind::vocabulary(gpu);
        for _ in 0..rng.below(20) + 1 {
            let k = *rng.choice(&vocab);
            if let Ok(n) = apply(&s, k, rng, gpu) {
                s = n;
            }
        }
        s.validate().map_err(|e| format!("invalid: {e}"))?;
        let lat = sim.latency(&s);
        if !(lat.is_finite() && lat > 0.0) {
            return Err(format!("bad latency {lat}"));
        }
        let f = features::featurize(&s, target);
        if f.iter().any(|x| !x.is_finite()) {
            return Err("non-finite feature".into());
        }
        Ok(())
    });
}

#[test]
fn prop_cost_model_scores_bounded() {
    check_prop("score-bounded", 10, 0xCAFE, |rng| {
        let sim = Simulator::new(Target::Cpu);
        let mut cm = CostModel::new(Target::Cpu, rng.next_u64());
        let base = Schedule::initial(Arc::new(workloads::gemm::gemm(256, 256, 256)));
        let vocab = TransformKind::vocabulary(false);
        for _ in 0..30 {
            let seq: Vec<_> = (0..1 + rng.below(3)).map(|_| *rng.choice(&vocab)).collect();
            if let Ok(s) = apply_sequence(&base, &seq, rng, false) {
                cm.measure(&sim, &s);
                let sc = cm.score(&s);
                if !(0.0..=1.0).contains(&sc) {
                    return Err(format!("score {sc} out of [0,1]"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_trace_replay_length_matches_applied() {
    check_prop("trace-grows", 20, 0xD00D, |rng| {
        let base = Schedule::initial(Arc::new(workloads::gemm::gemm(128, 128, 128)));
        let vocab = TransformKind::vocabulary(false);
        let mut s = base.clone();
        let mut applied = 0;
        for _ in 0..10 {
            if let Ok(n) = apply(&s, *rng.choice(&vocab), rng, false) {
                s = n;
                applied += 1;
            }
        }
        if s.trace.len() != applied {
            return Err(format!("trace {} != applied {applied}", s.trace.len()));
        }
        Ok(())
    });
}

#[test]
fn e2e_graph_speedup_composes() {
    let graph = workloads::llama_e2e::llama3_8b_graph();
    let r = coordinator::run_e2e(
        &graph,
        Target::Cpu,
        &Searcher::Coop {
            n: 4,
            largest: "gpt-5.2".into(),
        },
        90,
        11,
    );
    assert!(r.speedup > 1.0, "e2e speedup {}", r.speedup);
    assert!(r.n_samples >= 60);
}

#[test]
fn lambda_extremes_change_routing() {
    // λ=1 must route more to small models than λ=0
    let root = Schedule::initial(Arc::new(workloads::gemm::gemm(512, 512, 512)));
    let share_at = |lambda: f64| {
        let mut c = cfg(120, 13);
        c.lambda = lambda;
        let r = baselines::litecoop(8, "gpt-5.2", Target::Cpu, root.clone(), c, "gemm");
        let (reg, ca) = r.invocation_rate("gpt-5.2");
        reg + ca
    };
    let s0 = share_at(0.0);
    let s1 = share_at(1.0);
    assert!(
        s1 <= s0 + 0.05,
        "λ=1 largest share {s1} should not exceed λ=0 share {s0}"
    );
}

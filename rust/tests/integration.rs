//! Cross-module integration tests: search-over-simulator end-to-end,
//! paper-shape invariants, coordinator matrices, and property-based
//! storms over the full transform → simulate → featurize → predict path.

use litecoop::baselines;
use litecoop::benchutil::check_prop;
use litecoop::coordinator::{self, RunSpec, Searcher};
use litecoop::costmodel::{features, CostModel};
use litecoop::mcts::SearchConfig;
use litecoop::schedule::transforms::{apply, apply_sequence, TransformKind};
use litecoop::schedule::Schedule;
use litecoop::sim::{Simulator, Target};
use litecoop::util::Rng;
use litecoop::workloads;
use std::sync::Arc;

fn cfg(budget: usize, seed: u64) -> SearchConfig {
    SearchConfig {
        budget,
        seed,
        ..SearchConfig::default()
    }
}

#[test]
fn coop_beats_or_matches_single_small_model() {
    // a single small model should not dominate the 8-model collaboration
    let root = Schedule::initial(Arc::new(workloads::gemm::gemm(512, 512, 512)));
    let mut coop_sum = 0.0;
    let mut mini_sum = 0.0;
    for seed in 0..3 {
        coop_sum += baselines::litecoop(
            8,
            "gpt-5.2",
            Target::Cpu,
            root.clone(),
            cfg(100, seed),
            "gemm",
        )
        .best_speedup;
        mini_sum += baselines::single_llm(
            "gpt-5-mini",
            Target::Cpu,
            root.clone(),
            cfg(100, seed),
            "gemm",
        )
        .best_speedup;
    }
    assert!(
        coop_sum > mini_sum * 0.9,
        "coop {coop_sum} vs mini {mini_sum}"
    );
}

#[test]
fn coop_is_cheaper_than_single_large() {
    let root = Schedule::initial(Arc::new(workloads::mlp::llama4_mlp()));
    let single = baselines::single_llm(
        "gpt-5.2",
        Target::Cpu,
        root.clone(),
        cfg(120, 1),
        "llama4_mlp",
    );
    let coop = baselines::litecoop(8, "gpt-5.2", Target::Cpu, root, cfg(120, 1), "llama4_mlp");
    assert!(
        coop.api_cost_usd < single.api_cost_usd,
        "coop ${} !< single ${}",
        coop.api_cost_usd,
        single.api_cost_usd
    );
    assert!(
        coop.compile_time_s < single.compile_time_s,
        "coop {}s !< single {}s",
        coop.compile_time_s,
        single.compile_time_s
    );
}

#[test]
fn largest_model_share_drops_with_pool_size() {
    let root = Schedule::initial(Arc::new(workloads::moe::deepseek_moe()));
    let share = |n: usize| {
        // average over seeds: single runs are noisy
        (0..4)
            .map(|seed| {
                let r = baselines::litecoop(
                    n,
                    "gpt-5.2",
                    Target::Cpu,
                    root.clone(),
                    cfg(150, seed),
                    "moe",
                );
                let (reg, ca) = r.invocation_rate("gpt-5.2");
                reg + ca
            })
            .sum::<f64>()
            / 4.0
    };
    let s2 = share(2);
    let s8 = share(8);
    assert!(s8 < s2, "8-LLM largest share {s8} !< 2-LLM {s2}");
}

#[test]
fn every_paper_benchmark_searchable_on_both_targets() {
    for target in [Target::Cpu, Target::Gpu] {
        for w in workloads::paper_benchmarks() {
            let name = w.name.clone();
            let root = Schedule::initial(Arc::new(w));
            let r = baselines::litecoop(2, "gpt-5.2", target, root, cfg(40, 5), &name);
            assert!(
                r.best_speedup >= 1.0,
                "{name} on {target:?}: {}",
                r.best_speedup
            );
            assert!(r.best_schedule.validate().is_ok());
        }
    }
}

#[test]
fn coordinator_matrix_deterministic_across_thread_counts() {
    let specs: Vec<RunSpec> = (0..4)
        .map(|i| {
            RunSpec::new(
                "gemm",
                Target::Cpu,
                Searcher::Coop {
                    n: 4,
                    largest: "gpt-5.2".into(),
                },
                40,
                i,
            )
        })
        .collect();
    let a = coordinator::run_many(&specs, 1);
    let b = coordinator::run_many(&specs, 4);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.best_speedup, y.best_speedup);
    }
}

#[test]
fn driver_multi_workload_parallel_matches_serial() {
    use litecoop::runtime::driver;
    let searcher = Searcher::Coop {
        n: 2,
        largest: "gpt-5.2".into(),
    };
    let names = ["gemm", "llama4_mlp"];
    let par = driver::search_workloads(&names, Target::Cpu, &searcher, 40, 3, 4);
    let ser = driver::search_workloads(&names, Target::Cpu, &searcher, 40, 3, 1);
    for (x, y) in par.iter().zip(&ser) {
        assert_eq!(x.workload, y.workload);
        assert_eq!(x.best_speedup, y.best_speedup);
        assert_eq!(x.curve, y.curve);
        assert_eq!(x.api_cost_usd, y.api_cost_usd);
        assert_eq!(x.eval_cache, y.eval_cache);
    }
    // per-lane seeds are independent and deterministic; every search
    // consulted the evaluation cache
    assert_eq!(par[0].workload, "gemm");
    assert_eq!(par[1].workload, "llama4_mlp");
    assert!(par
        .iter()
        .all(|r| r.eval_cache.hits + r.eval_cache.misses > 0));
}

#[test]
fn prop_transform_storm_preserves_semantics_invariants() {
    // any sequence of transforms keeps: valid schedule, positive finite
    // latency on both targets, finite features
    check_prop("transform-storm", 30, 0xBEEF, |rng| {
        let w = workloads::paper_benchmarks()
            .into_iter()
            .nth(rng.below(5))
            .unwrap();
        let gpu = rng.chance(0.5);
        let target = if gpu { Target::Gpu } else { Target::Cpu };
        let sim = Simulator::new(target);
        let mut s = Schedule::initial(Arc::new(w));
        let vocab = TransformKind::vocabulary(gpu);
        for _ in 0..rng.below(20) + 1 {
            let k = *rng.choice(&vocab);
            if let Ok(n) = apply(&s, k, rng, gpu) {
                s = n;
            }
        }
        s.validate().map_err(|e| format!("invalid: {e}"))?;
        let lat = sim.latency(&s);
        if !(lat.is_finite() && lat > 0.0) {
            return Err(format!("bad latency {lat}"));
        }
        let f = features::featurize(&s, target);
        if f.iter().any(|x| !x.is_finite()) {
            return Err("non-finite feature".into());
        }
        Ok(())
    });
}

#[test]
fn prop_cost_model_scores_bounded() {
    check_prop("score-bounded", 10, 0xCAFE, |rng| {
        let sim = Simulator::new(Target::Cpu);
        let mut cm = CostModel::new(Target::Cpu, rng.next_u64());
        let base = Schedule::initial(Arc::new(workloads::gemm::gemm(256, 256, 256)));
        let vocab = TransformKind::vocabulary(false);
        for _ in 0..30 {
            let seq: Vec<_> = (0..1 + rng.below(3)).map(|_| *rng.choice(&vocab)).collect();
            if let Ok(s) = apply_sequence(&base, &seq, rng, false) {
                cm.measure(&sim, &s);
                let sc = cm.score(&s);
                if !(0.0..=1.0).contains(&sc) {
                    return Err(format!("score {sc} out of [0,1]"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_trace_replay_length_matches_applied() {
    check_prop("trace-grows", 20, 0xD00D, |rng| {
        let base = Schedule::initial(Arc::new(workloads::gemm::gemm(128, 128, 128)));
        let vocab = TransformKind::vocabulary(false);
        let mut s = base.clone();
        let mut applied = 0;
        for _ in 0..10 {
            if let Ok(n) = apply(&s, *rng.choice(&vocab), rng, false) {
                s = n;
                applied += 1;
            }
        }
        if s.trace.len() != applied {
            return Err(format!("trace {} != applied {applied}", s.trace.len()));
        }
        Ok(())
    });
}

#[test]
fn e2e_graph_speedup_composes() {
    let graph = workloads::llama_e2e::llama3_8b_graph();
    let r = coordinator::run_e2e(
        &graph,
        Target::Cpu,
        &Searcher::Coop {
            n: 4,
            largest: "gpt-5.2".into(),
        },
        90,
        11,
    );
    assert!(r.speedup > 1.0, "e2e speedup {}", r.speedup);
    assert!(r.n_samples >= 60);
}

#[test]
fn course_alteration_e2e_with_shared_cache() {
    // closes the long-standing gap: course alteration exercised
    // end-to-end *through a shared evaluation cache*, with per-search
    // counter isolation checked across cache re-adoption.
    use litecoop::llm::registry::paper_config;
    use litecoop::llm::ModelSet;
    use litecoop::mcts::evalcache::EvalCache;
    use litecoop::mcts::Mcts;

    let mk = |cache: EvalCache| {
        let sched = Schedule::initial(Arc::new(workloads::gemm::gemm(512, 512, 512)));
        let models = ModelSet::new(paper_config(8, "gpt-5.2"));
        let sim = Simulator::new(Target::Cpu);
        // ca_threshold = 1: a single persistent small-model regression
        // escalates — the most CA-heavy paper configuration (Appendix F)
        let cfg = SearchConfig {
            budget: 150,
            seed: 4,
            ca_threshold: Some(1),
            checkpoints: vec![75, 150],
            ..SearchConfig::default()
        };
        Mcts::with_cache(cfg, models, sim, sched, cache)
    };

    let (cold, cache) = mk(EvalCache::new()).run_with_cache("gemm");
    // persistent regressions actually escalated…
    assert!(cold.n_ca_events > 0, "CA never fired at threshold 1");
    // …and every CA call went to the largest model, nothing else
    let ca_total: usize = cold.call_counts.iter().map(|(_, _, c)| *c).sum();
    assert_eq!(ca_total, cold.n_ca_events);
    for (name, _, ca) in &cold.call_counts {
        if *ca > 0 {
            assert_eq!(name, "gpt-5.2", "CA call issued by non-largest model {name}");
        }
    }
    assert!(!cache.is_empty());

    // warm re-adoption: the second search replays identically, so its
    // lookup volume matches the cold run exactly — the counters are
    // per-search (reset on adoption), not accumulated across searches
    let (warm, _) = mk(cache).run_with_cache("gemm");
    assert_eq!(
        warm.eval_cache.hits + warm.eval_cache.misses,
        cold.eval_cache.hits + cold.eval_cache.misses,
        "per-search lookup volume drifted: warm {:?} vs cold {:?}",
        warm.eval_cache,
        cold.eval_cache
    );
    assert!(
        warm.eval_cache.hits > cold.eval_cache.hits,
        "warm run should serve ground truth from the shared cache"
    );
    assert!(warm.eval_cache.misses < cold.eval_cache.misses);
    // caching is transparent to the CA trajectory and the outcome
    assert_eq!(warm.n_ca_events, cold.n_ca_events);
    assert_eq!(warm.best_speedup, cold.best_speedup);
    assert_eq!(warm.curve, cold.curve);
}

#[test]
fn driver_search_threads_knob_is_transparent_and_deterministic() {
    use litecoop::runtime::driver;
    let searcher = Searcher::Coop {
        n: 2,
        largest: "gpt-5.2".into(),
    };
    let names = ["gemm"];
    // search_threads = 1 is the serial engine: identical to the plain API
    let plain = driver::search_workloads(&names, Target::Cpu, &searcher, 40, 3, 2);
    let st1 = driver::search_workloads_threaded(&names, Target::Cpu, &searcher, 40, 3, 2, 1);
    assert_eq!(plain[0].best_speedup, st1[0].best_speedup);
    assert_eq!(plain[0].curve, st1[0].curve);
    assert_eq!(plain[0].eval_cache, st1[0].eval_cache);
    // search_threads = 4 is deterministic regardless of the across-spec
    // thread pool size
    let a = driver::search_workloads_threaded(&names, Target::Cpu, &searcher, 40, 3, 2, 4);
    let b = driver::search_workloads_threaded(&names, Target::Cpu, &searcher, 40, 3, 1, 4);
    assert_eq!(a[0].best_speedup, b[0].best_speedup);
    assert_eq!(a[0].curve, b[0].curve);
    assert_eq!(a[0].eval_cache, b[0].eval_cache);
    assert_eq!(a[0].compile_time_s, b[0].compile_time_s);
}

#[test]
fn scenario_names_are_first_class_run_specs() {
    // a scenario-grammar workload flows through RunSpec → coordinator →
    // driver exactly like a registry name, deterministically
    let searcher = Searcher::Coop {
        n: 2,
        largest: "gpt-5.2".into(),
    };
    let spec = RunSpec::new(
        "moe@d_ff=64,d_model=32,experts=4,tokens=64,top_k=2",
        Target::Cpu,
        searcher,
        40,
        5,
    );
    let a = coordinator::run_one(&spec);
    let b = coordinator::run_one(&spec);
    assert_eq!(a.workload, spec.workload);
    assert_eq!(a.best_speedup, b.best_speedup);
    assert_eq!(a.curve, b.curve);
    assert!(a.best_speedup >= 1.0);
    assert!(a.best_schedule.validate().is_ok());
    // and the scenario point actually differs from the family default
    let default = workloads::by_name("moe").unwrap();
    assert_ne!(
        default.flops(),
        workloads::by_name(&spec.workload).unwrap().flops()
    );
}

#[test]
fn lambda_extremes_change_routing() {
    // λ=1 must route more to small models than λ=0
    let root = Schedule::initial(Arc::new(workloads::gemm::gemm(512, 512, 512)));
    let share_at = |lambda: f64| {
        let mut c = cfg(120, 13);
        c.lambda = lambda;
        let r = baselines::litecoop(8, "gpt-5.2", Target::Cpu, root.clone(), c, "gemm");
        let (reg, ca) = r.invocation_rate("gpt-5.2");
        reg + ca
    };
    let s0 = share_at(0.0);
    let s1 = share_at(1.0);
    assert!(
        s1 <= s0 + 0.05,
        "λ=1 largest share {s1} should not exceed λ=0 share {s0}"
    );
}

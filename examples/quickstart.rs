//! Quickstart: collaborative 2-LLM search on a plain GEMM, 120 samples.
//!
//!     cargo run --release --offline --example quickstart

use litecoop::baselines;
use litecoop::mcts::SearchConfig;
use litecoop::schedule::Schedule;
use litecoop::sim::Target;
use litecoop::workloads::gemm;
use std::sync::Arc;

fn main() {
    let root = Schedule::initial(Arc::new(gemm::gemm(1024, 1024, 1024)));
    let cfg = SearchConfig {
        budget: 120,
        seed: 1,
        ..SearchConfig::default()
    };
    println!("== LiteCoOp quickstart: GEMM 1024^3 on the CPU model, 2 LLMs ==");
    let r = baselines::litecoop(2, "gpt-5.2", Target::Cpu, root, cfg, "gemm");
    println!("speedup over unoptimized : {:.2}x", r.best_speedup);
    println!("simulated compile time   : {:.0}s", r.compile_time_s);
    println!("simulated API cost       : ${:.3}", r.api_cost_usd);
    println!("samples searched         : {}", r.n_samples);
    println!("\nbest schedule trace:\n{}", r.best_schedule.trace.render_tail(10));
    assert!(r.best_speedup > 1.0);
    println!("\nquickstart OK");
}

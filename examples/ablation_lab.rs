//! Ablation laboratory: λ sweep, course-alteration settings, and routing
//! policies on one benchmark — a fast interactive version of Appendices
//! D, F, and G.
//!
//!     cargo run --release --offline --example ablation_lab

use litecoop::coordinator::{run_one, RunSpec, Searcher};
use litecoop::sim::Target;

fn main() {
    let bench = "deepseek_moe";
    let budget = 150;

    println!("== λ sweep (Appendix D) on {bench}, CPU, LiteCoOp(8) ==");
    for lambda in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut spec = RunSpec::new(
            bench,
            Target::Cpu,
            Searcher::Coop {
                n: 8,
                largest: "gpt-5.2".into(),
            },
            budget,
            7,
        );
        spec.lambda = lambda;
        let r = run_one(&spec);
        let total: usize = r.call_counts.iter().map(|(_, a, b)| a + b).sum();
        let (lr, lc) = r.invocation_rate("gpt-5.2");
        println!(
            "λ={lambda:.2}: speedup {:.2}x  cost ${:.3}  largest share {:.1}% ({} calls total)",
            r.best_speedup,
            r.api_cost_usd,
            (lr + lc) * 100.0,
            total
        );
    }

    println!("\n== course alteration (Appendix F) ==");
    for (label, ca) in [("off", None), ("every-1", Some(1)), ("every-2", Some(2))] {
        let mut spec = RunSpec::new(
            bench,
            Target::Cpu,
            Searcher::Coop {
                n: 8,
                largest: "gpt-5.2".into(),
            },
            budget,
            7,
        );
        spec.ca_threshold = ca;
        let r = run_one(&spec);
        println!(
            "CA {label:<8}: speedup {:.2}x  CA events {}  time {:.0}s  cost ${:.3}",
            r.best_speedup, r.n_ca_events, r.compile_time_s, r.api_cost_usd
        );
    }

    println!("\n== routing (Appendix G) ==");
    let routers = [
        Searcher::Coop {
            n: 8,
            largest: "gpt-5.2".into(),
        },
        Searcher::RandomRouting {
            n: 8,
            largest: "gpt-5.2".into(),
        },
        Searcher::RoundRobinRouting {
            n: 8,
            largest: "gpt-5.2".into(),
        },
    ];
    for s in routers {
        let spec = RunSpec::new(bench, Target::Cpu, s.clone(), budget, 7);
        let r = run_one(&spec);
        println!(
            "{:<12}: speedup {:.2}x  time {:.0}s  cost ${:.3}",
            s.label(),
            r.best_speedup,
            r.compile_time_s,
            r.api_cost_usd
        );
    }
    println!("\nablation_lab OK");
}

//! END-TO-END driver (the required full-system workload): compile the
//! Llama-3-8B layer graph with LiteCoOp(8 LLMs), report speedup /
//! compile-time / API-cost vs the single-large baseline (paper Table 3),
//! and then prove all three layers compose by loading the AOT Llama block
//! artifact (Layer-2 JAX + Layer-1 Pallas flash-attention) and serving
//! batched executions through the PJRT runtime with latency stats.
//!
//!     make artifacts && cargo run --release --offline --example e2e_llama

use litecoop::coordinator::{run_e2e, Searcher};
use litecoop::runtime::Runtime;
use litecoop::sim::Target;
use litecoop::workloads::llama_e2e;

fn main() -> litecoop::Result<()> {
    let budget: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(240);

    // ---- Part 1: end-to-end schedule search over the layer graph --------
    let graph = llama_e2e::llama3_8b_graph();
    println!(
        "== e2e Llama-3-8B: {} unique tasks, {:.1} TFLOP total ==",
        graph.tasks.len(),
        graph.flops() / 1e12
    );
    for target in [Target::Gpu, Target::Cpu] {
        let single = run_e2e(
            &graph,
            target,
            &Searcher::Single("gpt-5.2".into()),
            budget,
            7,
        );
        let coop = run_e2e(
            &graph,
            target,
            &Searcher::Coop {
                n: 8,
                largest: "gpt-5.2".into(),
            },
            budget,
            7,
        );
        println!(
            "{}: single {:.2}x | LiteCoOp(8) {:.2}x ({:.2}x vs single), time red {:.2}x, cost red {:.2}x",
            target.name(),
            single.speedup,
            coop.speedup,
            coop.speedup / single.speedup,
            single.compile_time_s / coop.compile_time_s,
            single.api_cost_usd / coop.api_cost_usd
        );
    }

    // ---- Part 2: serve the real AOT artifact through PJRT ----------------
    println!("\n== PJRT serving: llama_block artifact (L2 JAX + L1 Pallas) ==");
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!("artifacts unavailable ({e}); run `make artifacts` first");
            return Ok(());
        }
    };
    println!("platform: {}", rt.platform());
    let art = rt.load("llama_block")?;
    let mut latencies = Vec::new();
    for batch in 0..8u64 {
        let inputs = rt.random_inputs(&art, 100 + batch)?;
        let t = std::time::Instant::now();
        let out = rt.execute(&art, &inputs)?;
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
        assert!(out.iter().all(|x| x.is_finite()), "non-finite output");
    }
    latencies.sort_by(|a, b| a.total_cmp(b));
    let mean: f64 = latencies.iter().sum::<f64>() / latencies.len() as f64;
    println!(
        "served {} requests: mean {:.2} ms, p50 {:.2} ms, p99 {:.2} ms, throughput {:.1} req/s",
        latencies.len(),
        mean,
        latencies[latencies.len() / 2],
        latencies[latencies.len() - 1],
        1000.0 / mean
    );
    println!("e2e_llama OK");
    Ok(())
}

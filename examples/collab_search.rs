//! Full collaborative search demo: 8 heterogeneous LLMs on the Llama-3-8B
//! attention layer, GPU and CPU targets, with invocation-rate breakdown —
//! the scenario of the paper's Figure 1/Table 2.
//!
//! All four searches (2 targets × {single-large, 8-LLM}) fan out through
//! the parallel multi-workload driver ([`litecoop::runtime::driver`]), so
//! the demo scales with cores while staying byte-identical to running
//! them serially. `--search-threads S` additionally runs each search
//! tree-parallel across S workers (deterministic per (seed, S)).
//!
//! `--sweep "family:key=v1,v2;key2=..."` switches to a scenario-matrix
//! sweep over the parameterized workload families (see
//! `workloads::scenarios`), and `--cache-file PATH` persists the
//! evaluation cache across processes — run the same sweep twice with
//! one file and the second run warm-starts from every ground-truth
//! evaluation the first performed.
//!
//!     cargo run --release --offline --example collab_search [budget] \
//!         [--search-threads S] [--cache-file PATH] \
//!         [--sweep "gemm:m=256,512;k=256"]

use litecoop::coordinator::{self, RunSpec, Searcher};
use litecoop::mcts::evalcache::EvalCache;
use litecoop::runtime::driver;
use litecoop::sim::Target;
use litecoop::util::cli::Args;
use litecoop::workloads::scenarios::ScenarioGrid;

/// Scenario-matrix mode: expand the grid, fan the sweep out through the
/// warm-start driver, report per-scenario speedups and cache reuse.
fn run_sweep(sweep: &str, budget: usize, search_threads: usize, cache_file: Option<&str>) {
    let scenarios = ScenarioGrid::parse_arg(sweep)
        .and_then(|g| g.expand())
        .unwrap_or_else(|e| {
            eprintln!("--sweep: {e}");
            std::process::exit(2);
        });
    let searcher = Searcher::Coop {
        n: 8,
        largest: "gpt-5.2".into(),
    };
    let specs = coordinator::sweep_specs(
        &scenarios,
        &[Target::Cpu],
        &searcher,
        budget,
        7,
        search_threads,
    );
    let initial = match cache_file {
        Some(p) => EvalCache::load_file_or_cold(p),
        None => EvalCache::new(),
    };
    let loaded = initial.len();
    println!(
        "== scenario sweep: {} scenarios, {budget} samples each, {loaded} warm entries ==",
        specs.len()
    );
    let (results, warmed) = driver::run_specs_warm(&specs, driver::default_threads(), initial);
    for (sp, r) in specs.iter().zip(&results) {
        println!(
            "{:<48} speedup {:>6.2}x  cache {:>5.1}% ({} hits / {} misses)",
            sp.workload,
            r.best_speedup,
            r.eval_cache.hit_rate() * 100.0,
            r.eval_cache.hits,
            r.eval_cache.misses
        );
    }
    let agg = driver::aggregate_cache(&results);
    println!(
        "\nwarm start: {loaded} entries loaded; sweep total {} hits / {} misses ({:.1}% hit rate)",
        agg.hits,
        agg.misses,
        agg.hit_rate() * 100.0
    );
    if let Some(p) = cache_file {
        match warmed.save_file(p) {
            Ok(()) => println!("eval cache saved: {} entries -> {p}", warmed.len()),
            Err(e) => eprintln!("warning: failed to save eval cache: {e}"),
        }
    }
}

fn main() {
    let args = Args::parse();
    let budget: usize = args
        .subcommand
        .as_deref()
        .and_then(|a| a.parse().ok())
        .unwrap_or_else(|| args.usize_or("budget", 300));
    let search_threads = args.usize_or("search-threads", 1).max(1);
    let cache_file = args.flag("cache-file").map(str::to_string);
    if let Some(sweep) = args.flag("sweep") {
        run_sweep(sweep, budget, search_threads, cache_file.as_deref());
        return;
    }

    // one spec per (target, searcher); the driver merges results in order
    let mut specs = Vec::new();
    for target in [Target::Gpu, Target::Cpu] {
        for searcher in [
            Searcher::Single("gpt-5.2".into()),
            Searcher::Coop {
                n: 8,
                largest: "gpt-5.2".into(),
            },
        ] {
            let mut sp = RunSpec::new("llama3_attention", target, searcher, budget, 7);
            sp.search_threads = search_threads;
            specs.push(sp);
        }
    }
    if search_threads > 1 {
        println!("tree-parallel search: {search_threads} threads per search\n");
    }
    // --cache-file: warm-start from (and persist back to) a cache file
    let results = driver::run_specs_cached(&specs, driver::default_threads(), cache_file.as_deref());

    for (pair, target) in results.chunks(2).zip([Target::Gpu, Target::Cpu]) {
        let (single, coop) = (&pair[0], &pair[1]);
        println!(
            "== 8-LLM collaborative search, {} target, {budget} samples ==",
            target.name()
        );
        println!(
            "single gpt-5.2 : speedup {:.2}x  time {:.0}s  cost ${:.2}",
            single.best_speedup, single.compile_time_s, single.api_cost_usd
        );
        println!(
            "LiteCoOp(8)    : speedup {:.2}x  time {:.0}s  cost ${:.2}  (time red {:.2}x, cost red {:.2}x)",
            coop.best_speedup,
            coop.compile_time_s,
            coop.api_cost_usd,
            single.compile_time_s / coop.compile_time_s,
            single.api_cost_usd / coop.api_cost_usd
        );
        let total: usize = coop.call_counts.iter().map(|(_, a, b)| a + b).sum();
        println!("invocation rates:");
        for (name, reg, ca) in &coop.call_counts {
            if reg + ca > 0 {
                println!(
                    "  {:<32} {:>5.1}%  ({} regular, {} course-alteration)",
                    name,
                    (reg + ca) as f64 / total as f64 * 100.0,
                    reg,
                    ca
                );
            }
        }
        println!(
            "eval cache     : {} hits / {} misses ({:.1}% hit rate)",
            coop.eval_cache.hits,
            coop.eval_cache.misses,
            coop.eval_cache.hit_rate() * 100.0
        );
        println!("speedup vs samples: {:?}\n", coop.curve);
    }

    let agg = driver::aggregate_cache(&results);
    println!(
        "driver total: {} runs, eval-cache {:.1}% hit rate ({} hits / {} misses)",
        results.len(),
        agg.hit_rate() * 100.0,
        agg.hits,
        agg.misses
    );
}

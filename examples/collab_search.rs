//! Full collaborative search demo: 8 heterogeneous LLMs on the Llama-3-8B
//! attention layer, GPU and CPU targets, with invocation-rate breakdown —
//! the scenario of the paper's Figure 1/Table 2.
//!
//!     cargo run --release --offline --example collab_search [budget]

use litecoop::baselines;
use litecoop::mcts::SearchConfig;
use litecoop::schedule::Schedule;
use litecoop::sim::Target;
use litecoop::workloads;
use std::sync::Arc;

fn main() {
    let budget: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(300);
    for target in [Target::Gpu, Target::Cpu] {
        let w = Arc::new(workloads::attention::llama3_attention());
        let root = Schedule::initial(w);
        let cfg = SearchConfig {
            budget,
            seed: 7,
            ..SearchConfig::default()
        };
        println!("== 8-LLM collaborative search, {} target, {budget} samples ==", target.name());
        let single = baselines::single_llm(
            "gpt-5.2",
            target,
            root.clone(),
            cfg.clone(),
            "llama3_attention",
        );
        let coop = baselines::litecoop(8, "gpt-5.2", target, root, cfg, "llama3_attention");
        println!(
            "single gpt-5.2 : speedup {:.2}x  time {:.0}s  cost ${:.2}",
            single.best_speedup, single.compile_time_s, single.api_cost_usd
        );
        println!(
            "LiteCoOp(8)    : speedup {:.2}x  time {:.0}s  cost ${:.2}  (time red {:.2}x, cost red {:.2}x)",
            coop.best_speedup,
            coop.compile_time_s,
            coop.api_cost_usd,
            single.compile_time_s / coop.compile_time_s,
            single.api_cost_usd / coop.api_cost_usd
        );
        let total: usize = coop.call_counts.iter().map(|(_, a, b)| a + b).sum();
        println!("invocation rates:");
        for (name, reg, ca) in &coop.call_counts {
            if reg + ca > 0 {
                println!(
                    "  {:<32} {:>5.1}%  ({} regular, {} course-alteration)",
                    name,
                    (reg + ca) as f64 / total as f64 * 100.0,
                    reg,
                    ca
                );
            }
        }
        println!("speedup vs samples: {:?}\n", coop.curve);
    }
}
